//! Compressed sparse weight layouts (CSR / BSR, exact and quantised) and
//! their SpMM kernels.
//!
//! PERP keeps pruned networks pruned, but the masked kernels
//! (`linalg::matmul_nt_masked` / `matmul_masked`) still stream the full
//! dense `(m, k)` weight *and* mask buffers and branch per element — a
//! 90%-sparse layer pays almost the same memory traffic as a dense one.
//! Four compressed forms fix that at different operating points:
//!
//! * [`CsrMatrix`] — classic compressed rows: only the `nnz` surviving
//!   weights are stored and touched.  Wins at high unstructured sparsity;
//!   loses at moderate sparsity because the scalar gather does not
//!   vectorise.
//! * [`BsrMatrix`] — block-sparse rows: dense `R×C` value tiles (1×4 for
//!   2:4-structured masks, where every aligned group of four columns keeps
//!   at most two survivors and so every 1×4 block is live; 4×4 otherwise).
//!   Inner loops run over dense tiles with independent per-output
//!   accumulators, so the FMA chains pipeline instead of serialising.
//! * [`QuantCsr`] / [`QuantBsr`] — the same index structures with `f16` or
//!   `i8` values (per-matrix-row scales, dequantised in-register inside
//!   the dot product).  These are *approximate* (`i8` error ≤ scale·0.5
//!   per entry), so they are decode/eval-only and never auto-selected on
//!   paths that pin bitwise parity.
//!
//! All exact kernels mirror the masked kernels' per-element accumulation
//! order (one accumulator per output element, contributions in ascending
//! column order).  BSR tiles additionally store explicit zeros for pruned
//! entries inside a live block; adding those `a·0.0` terms is an IEEE
//! accumulation identity (the accumulator starts at +0.0 and can never
//! become −0.0 through additions), so dense/masked/csr/bsr stay
//! bit-identical — pinned by the unit tests here and by
//! `tests/decode_parity.rs`.
//!
//! Layout *selection* lives here too: [`WeightLayout`] names the execution
//! strategies and [`LayoutPolicy`] resolves one per layer from its measured
//! sparsity and structure.  [`LayoutPolicy::Auto`] consults the *measured*
//! [`CrossoverTable`] written by `repro bench-kernels` (cached under
//! `results/bench_kernels.json`, advertised via `PERP_CROSSOVER_TABLE`)
//! and falls back to the single `PERP_CSR_CROSSOVER` threshold (default
//! 0.75) when no table has been measured yet.  [`SparseStore`] is the
//! cached, named collection the coordinator builds once at prune / merge /
//! load-checkpoint time and feeds to every subsequent execution.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::OnceLock;

use rayon::prelude::*;

use super::{pool, Tensor};
use crate::util::json::Json;
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Layout selection.
// ---------------------------------------------------------------------------

/// How a masked linear's `x @ (W⊙M)ᵀ` contraction is executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightLayout {
    /// Materialise `W⊙M` and run the dense kernel (the pre-fusion baseline).
    Dense,
    /// Fused masked kernels: read W and M, skip pruned entries per element.
    Masked,
    /// Compressed rows: touch only surviving weights.
    Csr,
    /// Block-sparse rows: dense value tiles, vectorisable inner loops.
    Bsr,
    /// CSR with f16 values (approximate; decode/eval only).
    CsrF16,
    /// CSR with i8 values + per-row scales (approximate; decode/eval only).
    CsrQ8,
    /// BSR with f16 values (approximate; decode/eval only).
    BsrF16,
    /// BSR with i8 values + per-row scales (approximate; decode/eval only).
    BsrQ8,
}

impl WeightLayout {
    pub fn name(&self) -> &'static str {
        match self {
            WeightLayout::Dense => "dense",
            WeightLayout::Masked => "masked",
            WeightLayout::Csr => "csr",
            WeightLayout::Bsr => "bsr",
            WeightLayout::CsrF16 => "csr-f16",
            WeightLayout::CsrQ8 => "csr-q8",
            WeightLayout::BsrF16 => "bsr-f16",
            WeightLayout::BsrQ8 => "bsr-q8",
        }
    }

    pub fn parse(s: &str) -> Option<WeightLayout> {
        Some(match s {
            "dense" => WeightLayout::Dense,
            "masked" => WeightLayout::Masked,
            "csr" => WeightLayout::Csr,
            "bsr" => WeightLayout::Bsr,
            "csr-f16" => WeightLayout::CsrF16,
            "csr-q8" => WeightLayout::CsrQ8,
            "bsr-f16" => WeightLayout::BsrF16,
            "bsr-q8" => WeightLayout::BsrQ8,
            _ => return None,
        })
    }

    /// Approximate layouts: results differ from the masked reference, so
    /// they are barred from training/backward and from auto-selection on
    /// bitwise-pinned paths.
    pub fn is_quantised(&self) -> bool {
        matches!(
            self,
            WeightLayout::CsrF16 | WeightLayout::CsrQ8 | WeightLayout::BsrF16 | WeightLayout::BsrQ8
        )
    }

    /// The exact layout a quantised one degrades to (identity for exact).
    pub fn exact_counterpart(&self) -> WeightLayout {
        match self {
            WeightLayout::CsrF16 | WeightLayout::CsrQ8 => WeightLayout::Csr,
            WeightLayout::BsrF16 | WeightLayout::BsrQ8 => WeightLayout::Bsr,
            other => *other,
        }
    }
}

/// The layout / policy strings `--layout` accepts.
pub const ALLOWED_LAYOUTS: &str =
    "auto|auto-q|dense|masked|csr|bsr|csr-f16|csr-q8|bsr-f16|bsr-q8";

/// Per-layer layout choice: forced, or resolved from measured sparsity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutPolicy {
    /// Pick an *exact* layout per layer from the measured crossover table
    /// (fallback heuristic: BSR for 2:4-structured masks, CSR at or above
    /// the crossover sparsity, fused masked kernels below it).
    Auto,
    /// Like [`LayoutPolicy::Auto`] but quantised layouts are allowed — an
    /// explicit opt-in for decode/eval paths that tolerate approximation.
    AutoQuant,
    /// One layout for every layer (`--layout dense|masked|csr|bsr|...`).
    Fixed(WeightLayout),
}

impl LayoutPolicy {
    pub fn parse(s: &str) -> Result<LayoutPolicy, String> {
        match s {
            "auto" => Ok(LayoutPolicy::Auto),
            "auto-q" => Ok(LayoutPolicy::AutoQuant),
            other => WeightLayout::parse(other).map(LayoutPolicy::Fixed).ok_or_else(|| {
                format!("unknown layout {other:?} (allowed: {ALLOWED_LAYOUTS})")
            }),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LayoutPolicy::Auto => "auto",
            LayoutPolicy::AutoQuant => "auto-q",
            LayoutPolicy::Fixed(l) => l.name(),
        }
    }

    /// Whether this policy can ever route a layer to an approximate layout.
    /// Callers with bitwise-parity pins (training, cached-artifact reuse)
    /// gate on this.
    pub fn may_quantise(&self) -> bool {
        match self {
            LayoutPolicy::AutoQuant => true,
            LayoutPolicy::Fixed(l) => l.is_quantised(),
            LayoutPolicy::Auto => false,
        }
    }

    /// Sparsity at which CSR overtakes the fused masked kernel — the
    /// fallback when no measured [`CrossoverTable`] is available.
    /// `PERP_CSR_CROSSOVER` overrides the default for other machines.
    pub fn csr_crossover() -> f64 {
        std::env::var("PERP_CSR_CROSSOVER")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|v| (0.0..=1.0).contains(v))
            .unwrap_or(0.75)
    }

    /// Resolve the layout for one layer from its measured sparsity and
    /// whether its mask is 2:4-structured, consulting the process-wide
    /// measured crossover table when one was advertised.
    pub fn resolve(&self, sparsity: f64, structured: bool) -> WeightLayout {
        self.resolve_with(sparsity, structured, CrossoverTable::cached())
    }

    /// [`LayoutPolicy::resolve`] against an explicit table (unit-testable:
    /// the dispatcher must pick the table's argmax per layer).
    pub fn resolve_with(
        &self,
        sparsity: f64,
        structured: bool,
        table: Option<&CrossoverTable>,
    ) -> WeightLayout {
        let quant = match self {
            LayoutPolicy::Fixed(l) => return *l,
            LayoutPolicy::Auto => false,
            LayoutPolicy::AutoQuant => true,
        };
        if let Some(best) = table.and_then(|t| t.best(sparsity, structured, quant)) {
            // Auto must stay exact even if a table claims otherwise.
            return if quant { best } else { best.exact_counterpart() };
        }
        // No measurements yet: single-threshold heuristic.
        let base = if structured {
            WeightLayout::Bsr
        } else if sparsity >= Self::csr_crossover() {
            WeightLayout::Csr
        } else {
            WeightLayout::Masked
        };
        match (quant, base) {
            (true, WeightLayout::Csr) => WeightLayout::CsrQ8,
            (true, WeightLayout::Bsr) => WeightLayout::BsrQ8,
            (_, other) => other,
        }
    }
}

impl std::str::FromStr for LayoutPolicy {
    type Err = String;
    fn from_str(s: &str) -> Result<LayoutPolicy, String> {
        LayoutPolicy::parse(s)
    }
}

// ---------------------------------------------------------------------------
// Measured crossover table.
// ---------------------------------------------------------------------------

/// One measured operating point: at `sparsity` (and mask structure), which
/// layout had the lowest summed forward+backward time across the bench
/// shapes.  `best_exact` is restricted to bitwise-exact layouts;
/// `best_any` may name a quantised one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrossoverEntry {
    pub sparsity: f64,
    pub structured: bool,
    pub best_exact: WeightLayout,
    pub best_any: WeightLayout,
}

/// The measured layout-crossover table `repro bench-kernels` embeds in
/// `results/bench_kernels.json` under the `"crossover"` key.  `--layout
/// auto` consumes it via [`CrossoverTable::cached`]: the CLI points
/// `PERP_CROSSOVER_TABLE` at the report once one exists, replacing the
/// single hard-coded threshold with per-operating-point measurements.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CrossoverTable {
    pub entries: Vec<CrossoverEntry>,
}

impl CrossoverTable {
    /// Parse the `"crossover"` array out of a bench-kernels report.
    pub fn from_json(report: &Json) -> Result<CrossoverTable, String> {
        let arr = report
            .get("crossover")
            .and_then(Json::as_arr)
            .ok_or_else(|| "report has no \"crossover\" array".to_string())?;
        let mut entries = Vec::with_capacity(arr.len());
        for e in arr {
            let sparsity = e
                .get("sparsity")
                .and_then(Json::as_f64)
                .ok_or_else(|| "crossover entry missing sparsity".to_string())?;
            let pattern = e.get("pattern").and_then(Json::as_str).unwrap_or("unstructured");
            let parse_layout = |key: &str| -> Result<Option<WeightLayout>, String> {
                match e.get(key).and_then(Json::as_str) {
                    None => Ok(None),
                    Some(s) => WeightLayout::parse(s)
                        .map(Some)
                        .ok_or_else(|| format!("crossover entry has unknown layout {s:?}")),
                }
            };
            let best_exact = parse_layout("best_exact")?
                .ok_or_else(|| "crossover entry missing best_exact".to_string())?;
            if best_exact.is_quantised() {
                return Err(format!(
                    "crossover best_exact {} is quantised — table rejected",
                    best_exact.name()
                ));
            }
            let best_any = parse_layout("best_any")?.unwrap_or(best_exact);
            entries.push(CrossoverEntry {
                sparsity,
                structured: pattern != "unstructured",
                best_exact,
                best_any,
            });
        }
        Ok(CrossoverTable { entries })
    }

    /// Load from a bench-kernels report file; `None` on any read/parse
    /// failure (auto-dispatch then falls back to the threshold heuristic).
    pub fn load(path: &Path) -> Option<CrossoverTable> {
        let text = std::fs::read_to_string(path).ok()?;
        let json = Json::parse(&text).ok()?;
        CrossoverTable::from_json(&json).ok()
    }

    /// The process-wide table, loaded once from the file named by
    /// `PERP_CROSSOVER_TABLE` (set by the CLI when a measured
    /// `results/bench_kernels.json` exists).  Reading only the env var
    /// keeps unit tests hermetic.
    pub fn cached() -> Option<&'static CrossoverTable> {
        static CACHE: OnceLock<Option<CrossoverTable>> = OnceLock::new();
        CACHE
            .get_or_init(|| {
                std::env::var("PERP_CROSSOVER_TABLE")
                    .ok()
                    .and_then(|p| CrossoverTable::load(Path::new(&p)))
            })
            .as_ref()
    }

    /// Best measured layout for an operating point: entries matching the
    /// mask structure are preferred, then the nearest measured sparsity.
    pub fn best(&self, sparsity: f64, structured: bool, quant: bool) -> Option<WeightLayout> {
        let pick = |es: &[&CrossoverEntry]| -> Option<WeightLayout> {
            es.iter()
                .min_by(|a, b| {
                    let da = (a.sparsity - sparsity).abs();
                    let db = (b.sparsity - sparsity).abs();
                    da.partial_cmp(&db).unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|e| if quant { e.best_any } else { e.best_exact })
        };
        let matching: Vec<&CrossoverEntry> =
            self.entries.iter().filter(|e| e.structured == structured).collect();
        if !matching.is_empty() {
            return pick(&matching);
        }
        let all: Vec<&CrossoverEntry> = self.entries.iter().collect();
        pick(&all)
    }
}

// ---------------------------------------------------------------------------
// Mask-structure probe.
// ---------------------------------------------------------------------------

/// True when `w ⊙ mask` satisfies n:m semi-structured sparsity: `cols`
/// divides into aligned groups of `m` and every group keeps at most `n`
/// non-zeros.  Used to pick the 1×4 BSR block size for 2:4 masks.
pub fn is_nm_structured(w: &Tensor, mask: &Tensor, n: usize, m: usize) -> bool {
    let (rows, cols) = (w.rows(), w.cols());
    if m == 0 || cols % m != 0 {
        return false;
    }
    let (wd, md) = (w.data(), mask.data());
    for i in 0..rows {
        let row = i * cols;
        for g in (0..cols).step_by(m) {
            let mut kept = 0usize;
            for t in 0..m {
                if wd[row + g + t] * md[row + g + t] != 0.0 {
                    kept += 1;
                }
            }
            if kept > n {
                return false;
            }
        }
    }
    true
}

// ---------------------------------------------------------------------------
// f16 bit conversion (no half-float dependency).
// ---------------------------------------------------------------------------

/// f32 → IEEE binary16 bits: round-to-nearest-even, overflow saturates to
/// ±65504 (weights never legitimately overflow f16; saturation keeps the
/// kernels NaN-free), subnormals handled exactly.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7bff; // saturate to ±65504
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows to ±0
        }
        // subnormal: shift the (implicit-1) mantissa into place, RNE
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let rounded = (man + (1 << (shift - 1)) - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // normal: drop 13 mantissa bits with round-to-nearest-even
    let rounded = man + 0x0fff + ((man >> 13) & 1);
    let mut e16 = e as u32;
    let mut man16 = rounded >> 13;
    if man16 >= 0x400 {
        man16 = 0;
        e16 += 1;
    }
    if e16 >= 0x1f {
        return sign | 0x7bff;
    }
    sign | ((e16 as u16) << 10) | man16 as u16
}

/// IEEE binary16 bits → f32 (exact: every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
    let e = ((h >> 10) & 0x1f) as i32;
    let man = (h & 0x3ff) as f32;
    match e {
        0 => sign * man * (2.0f32).powi(-24),
        0x1f => {
            if man == 0.0 {
                sign * f32::INFINITY
            } else {
                f32::NAN
            }
        }
        _ => sign * (1.0 + man / 1024.0) * (2.0f32).powi(e - 15),
    }
}

// ---------------------------------------------------------------------------
// CSR matrix.
// ---------------------------------------------------------------------------

/// Compressed-sparse-row form of a 2-D weight matrix, built once from
/// `W ⊙ M`.  Entries are the coordinates where the product is non-zero, in
/// row-major / ascending-column order — the same traversal order as the
/// masked kernels, which keeps cross-layout results aligned.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// `rows + 1` offsets into `col_idx`/`values`.
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    values: Vec<f32>,
}

impl CsrMatrix {
    /// Compress the non-zeros of `w ⊙ mask` (an all-ones mask therefore
    /// compresses the non-zeros of `w` itself — the checkpoint-serving case,
    /// where pruned weights carry their zeros in the values).
    pub fn from_dense_masked(w: &Tensor, mask: &Tensor) -> CsrMatrix {
        assert_eq!(w.shape(), mask.shape(), "mask must be shaped like w");
        let (m, k) = (w.rows(), w.cols());
        // row_ptr stores nnz as u32 and nnz <= m·k, so bound the product
        assert!(m * k <= u32::MAX as usize, "matrix too large for u32 CSR offsets");
        let (wd, md) = (w.data(), mask.data());
        let mut row_ptr = Vec::with_capacity(m + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..m {
            for j in 0..k {
                let v = wd[i * k + j] * md[i * k + j];
                if v != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        CsrMatrix { rows: m, cols: k, row_ptr, col_idx, values }
    }

    /// Decompress back to a dense `(rows, cols)` tensor (dropped entries
    /// come back as exact 0.0).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                out[i * self.cols + c as usize] = v;
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.col_idx[lo..hi], &self.values[lo..hi])
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of entries *not* stored.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Bytes spent on values alone (`nnz × 4`).
    pub fn value_bytes(&self) -> usize {
        self.nnz() * 4
    }

    /// Compressed footprint: `nnz × 8 B + (rows + 1) × 4 B` (values +
    /// col-idx per entry, plus the row-pointer array).
    pub fn mem_bytes(&self) -> usize {
        self.nnz() * 8 + self.row_ptr.len() * 4
    }

    /// Dense footprint of the same matrix (`rows · cols × 4 B`).
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Dot products for output columns `j0 .. j0+out.len()` of one
    /// activation row — the per-chunk unit both the SpMM driver and the
    /// fused q/k/v decode kernel dispatch to.
    #[inline]
    pub fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        for (jj, o) in out.iter_mut().enumerate() {
            let (cols, vals) = self.row(j0 + jj);
            *o = csr_dot(arow, cols, vals);
        }
    }
}

// ---------------------------------------------------------------------------
// BSR matrix.
// ---------------------------------------------------------------------------

/// Largest supported block height (accumulator array size in the lockstep
/// kernels).
const MAX_BR: usize = 8;

/// Block-sparse-row form of a 2-D weight matrix: only blocks with at least
/// one survivor of `W ⊙ M` are stored, as dense row-major `br×bc` tiles
/// (pruned entries inside a live tile are explicit 0.0).  2:4 masks use
/// 1×4 tiles — every aligned group of four keeps ≥1 survivor at 50%, so
/// the block structure is fully dense and the inner loops stream
/// sequentially; unstructured masks default to 4×4 tiles.
#[derive(Debug, Clone, PartialEq)]
pub struct BsrMatrix {
    rows: usize,
    cols: usize,
    br: usize,
    bc: usize,
    /// `n_block_rows + 1` offsets into `block_col`.
    row_ptr: Vec<u32>,
    /// Block-column index (in units of `bc`) per stored block, ascending
    /// within each block row.
    block_col: Vec<u32>,
    /// `n_blocks × br × bc` tile values, row-major within each tile.
    values: Vec<f32>,
    /// Per block row: does it store *all* `ceil(cols/bc)` blocks?  Full
    /// rows take the lockstep fast path (always true for 2:4 masks).
    full: Vec<bool>,
}

impl BsrMatrix {
    /// The native block shape for a mask: 1×4 when 2:4-structured (tiles
    /// align with the n:m groups), 4×4 otherwise.
    pub fn native_block(structured: bool) -> (usize, usize) {
        if structured {
            (1, 4)
        } else {
            (4, 4)
        }
    }

    /// Compress `w ⊙ mask` into `br×bc` tiles, keeping any tile with at
    /// least one non-zero.
    pub fn from_dense_masked(w: &Tensor, mask: &Tensor, br: usize, bc: usize) -> BsrMatrix {
        assert_eq!(w.shape(), mask.shape(), "mask must be shaped like w");
        assert!(br >= 1 && br <= MAX_BR && bc >= 1, "unsupported block shape {br}x{bc}");
        let (m, k) = (w.rows(), w.cols());
        assert!(m * k <= u32::MAX as usize, "matrix too large for u32 BSR offsets");
        let (wd, md) = (w.data(), mask.data());
        let nbr = m.div_ceil(br);
        let nbc = k.div_ceil(bc);
        let mut row_ptr = Vec::with_capacity(nbr + 1);
        let mut block_col = Vec::new();
        let mut values = Vec::new();
        let mut full = Vec::with_capacity(nbr);
        row_ptr.push(0u32);
        let mut tile = vec![0.0f32; br * bc];
        for bi in 0..nbr {
            let row_start = block_col.len();
            for bj in 0..nbc {
                tile.iter_mut().for_each(|t| *t = 0.0);
                let mut live = false;
                for rr in 0..br.min(m - bi * br) {
                    let i = bi * br + rr;
                    for t in 0..bc.min(k - bj * bc) {
                        let j = bj * bc + t;
                        let v = wd[i * k + j] * md[i * k + j];
                        if v != 0.0 {
                            live = true;
                        }
                        tile[rr * bc + t] = v;
                    }
                }
                if live {
                    block_col.push(bj as u32);
                    values.extend_from_slice(&tile);
                }
            }
            full.push(block_col.len() - row_start == nbc);
            row_ptr.push(block_col.len() as u32);
        }
        BsrMatrix { rows: m, cols: k, br, bc, row_ptr, block_col, values, full }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn block_shape(&self) -> (usize, usize) {
        (self.br, self.bc)
    }
    pub fn n_blocks(&self) -> usize {
        self.block_col.len()
    }

    /// Stored entries (block area × block count) — includes the explicit
    /// zeros padding partially-live tiles.
    pub fn stored(&self) -> usize {
        self.n_blocks() * self.br * self.bc
    }

    /// Bytes spent on values alone.
    pub fn value_bytes(&self) -> usize {
        self.stored() * 4
    }

    /// Compressed footprint: tile values + block-col indices + row
    /// pointers.
    pub fn mem_bytes(&self) -> usize {
        self.value_bytes() + self.block_col.len() * 4 + self.row_ptr.len() * 4
    }

    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Decompress back to dense (entries outside stored blocks and pruned
    /// entries inside them come back as exact 0.0).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let (br, bc) = (self.br, self.bc);
        for bi in 0..self.full.len() {
            let lo = self.row_ptr[bi] as usize;
            let hi = self.row_ptr[bi + 1] as usize;
            for b in lo..hi {
                let bj = self.block_col[b] as usize;
                let tile = &self.values[b * br * bc..(b + 1) * br * bc];
                for rr in 0..br.min(self.rows - bi * br) {
                    for t in 0..bc.min(self.cols - bj * bc) {
                        out[(bi * br + rr) * self.cols + bj * bc + t] = tile[rr * bc + t];
                    }
                }
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }

    /// Dot of one activation row against matrix row `i` (scalar reference
    /// path; ascending-column accumulation, one accumulator).
    #[inline]
    fn dot_one(&self, arow: &[f32], i: usize) -> f32 {
        let (br, bc) = (self.br, self.bc);
        let (bi, rr) = (i / br, i % br);
        let lo = self.row_ptr[bi] as usize;
        let hi = self.row_ptr[bi + 1] as usize;
        let mut acc = 0.0f32;
        for b in lo..hi {
            let cb = self.block_col[b] as usize * bc;
            let width = bc.min(self.cols - cb);
            let trow = &self.values[b * br * bc + rr * bc..][..width];
            let a = &arow[cb..cb + width];
            for t in 0..width {
                acc += a[t] * trow[t];
            }
        }
        acc
    }

    /// One aligned block row (`br` outputs) with `br` independent
    /// accumulators: the FMA chains of the `br` output elements interleave,
    /// hiding the add latency that serialises the scalar path.  Each
    /// accumulator still sums in ascending column order, so results are
    /// bitwise identical to [`BsrMatrix::dot_one`].
    #[inline]
    fn block_row_lockstep(&self, arow: &[f32], bi: usize, out: &mut [f32]) {
        let (br, bc) = (self.br, self.bc);
        let lo = self.row_ptr[bi] as usize;
        let hi = self.row_ptr[bi + 1] as usize;
        let mut acc = [0.0f32; MAX_BR];
        for b in lo..hi {
            let cb = self.block_col[b] as usize * bc;
            let width = bc.min(self.cols - cb);
            let tile = &self.values[b * br * bc..(b + 1) * br * bc];
            let a = &arow[cb..cb + width];
            for rr in 0..br {
                let trow = &tile[rr * bc..rr * bc + width];
                let mut s = acc[rr];
                for t in 0..width {
                    s += a[t] * trow[t];
                }
                acc[rr] = s;
            }
        }
        out.copy_from_slice(&acc[..br]);
    }

    /// Four consecutive *full* 1-high block rows in lockstep (the 2:4 hot
    /// path: every block row is full, block `b` sits at column `b·bc`, so
    /// the tile stream is fully sequential and four output accumulators
    /// pipeline together).
    #[inline]
    fn four_full_rows(&self, arow: &[f32], i0: usize, out: &mut [f32]) {
        let bc = self.bc;
        let nbc = self.cols.div_ceil(bc);
        let base = [
            self.row_ptr[i0] as usize * bc,
            self.row_ptr[i0 + 1] as usize * bc,
            self.row_ptr[i0 + 2] as usize * bc,
            self.row_ptr[i0 + 3] as usize * bc,
        ];
        let mut acc = [0.0f32; 4];
        for b in 0..nbc {
            let cb = b * bc;
            let width = bc.min(self.cols - cb);
            let a = &arow[cb..cb + width];
            for r in 0..4 {
                let trow = &self.values[base[r] + b * bc..][..width];
                let mut s = acc[r];
                for t in 0..width {
                    s += a[t] * trow[t];
                }
                acc[r] = s;
            }
        }
        out.copy_from_slice(&acc);
    }

    /// Dot products for output columns `j0 .. j0+out.len()` of one
    /// activation row.  Chunks are routed to the lockstep kernels wherever
    /// alignment allows and fall back to the scalar path at ragged tails —
    /// all paths accumulate identically, so chunking never changes bits.
    pub fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        if self.br == 1 {
            let mut jj = 0usize;
            while jj < out.len() {
                let i = j0 + jj;
                if jj + 4 <= out.len()
                    && self.full[i]
                    && self.full[i + 1]
                    && self.full[i + 2]
                    && self.full[i + 3]
                {
                    self.four_full_rows(arow, i, &mut out[jj..jj + 4]);
                    jj += 4;
                } else {
                    out[jj] = self.dot_one(arow, i);
                    jj += 1;
                }
            }
            return;
        }
        let br = self.br;
        let mut jj = 0usize;
        while jj < out.len() {
            let i = j0 + jj;
            let take = (br - i % br).min(out.len() - jj);
            if i % br == 0 && take == br {
                self.block_row_lockstep(arow, i / br, &mut out[jj..jj + br]);
            } else {
                for t in 0..take {
                    out[jj + t] = self.dot_one(arow, i + t);
                }
            }
            jj += take;
        }
    }

    /// `a:(n,k) @ W:(m,k)ᵀ -> (n,m)` — forward / decode contraction.
    pub fn spmm_nt(&self, a: &Tensor) -> Tensor {
        let (n, k) = (a.rows(), a.cols());
        assert_eq!(k, self.cols, "bsr spmm_nt inner-dim mismatch {k} vs {}", self.cols);
        let m = self.rows;
        let mut out = pool::zeroed(n * m);
        let ad = a.data();
        if n == 1 {
            out.par_chunks_mut(COLS_PER_TASK).enumerate().for_each(|(cj, chunk)| {
                self.dots_range(ad, cj * COLS_PER_TASK, chunk);
            });
        } else {
            out.par_chunks_mut(ROWS_PER_TASK * m).enumerate().for_each(|(ci, chunk)| {
                let i0 = ci * ROWS_PER_TASK;
                for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                    let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                    self.dots_range(arow, 0, orow);
                }
            });
        }
        Tensor::new(&[n, m], out)
    }

    /// `a:(n,m) @ W:(m,k) -> (n,k)` — backward-dx contraction.  Exact
    /// zeros of `a` are skipped; each consumed element scatters its
    /// matrix row's tiles contiguously.
    pub fn spmm(&self, a: &Tensor) -> Tensor {
        let (n, m) = (a.rows(), a.cols());
        assert_eq!(m, self.rows, "bsr spmm inner-dim mismatch {m} vs {}", self.rows);
        let k = self.cols;
        let (br, bc) = (self.br, self.bc);
        let mut out = pool::zeroed(n * k);
        let ad = a.data();
        out.par_chunks_mut(ROWS_PER_TASK * k).enumerate().for_each(|(ci, chunk)| {
            let i0 = ci * ROWS_PER_TASK;
            for (ii, orow) in chunk.chunks_mut(k).enumerate() {
                let arow = &ad[(i0 + ii) * m..(i0 + ii + 1) * m];
                for (j, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let (bi, rr) = (j / br, j % br);
                    let lo = self.row_ptr[bi] as usize;
                    let hi = self.row_ptr[bi + 1] as usize;
                    for b in lo..hi {
                        let cb = self.block_col[b] as usize * bc;
                        let width = bc.min(k - cb);
                        let trow = &self.values[b * br * bc + rr * bc..][..width];
                        let orun = &mut orow[cb..cb + width];
                        for t in 0..width {
                            orun[t] += av * trow[t];
                        }
                    }
                }
            }
        });
        Tensor::new(&[n, k], out)
    }
}

// ---------------------------------------------------------------------------
// Quantised value storage (decode/eval only).
// ---------------------------------------------------------------------------

/// Which reduced-precision value encoding a quantised form uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantKind {
    /// IEEE binary16 per value — ~1e-3 relative error, half the bytes.
    F16,
    /// i8 per value + one f32 scale per matrix row — error ≤ scale·0.5,
    /// a quarter of the bytes (amortised).
    I8,
}

/// Quantised values: the payload both [`QuantCsr`] and [`QuantBsr`] carry.
#[derive(Debug, Clone, PartialEq)]
enum QVals {
    F16(Vec<u16>),
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

impl QVals {
    fn quantise(values: &[f32], kind: QuantKind, row_of: impl Fn(usize) -> usize, rows: usize) -> QVals {
        match kind {
            QuantKind::F16 => QVals::F16(values.iter().map(|&v| f32_to_f16_bits(v)).collect()),
            QuantKind::I8 => {
                let mut maxabs = vec![0.0f32; rows];
                for (idx, &v) in values.iter().enumerate() {
                    let r = row_of(idx);
                    if v.abs() > maxabs[r] {
                        maxabs[r] = v.abs();
                    }
                }
                let scales: Vec<f32> = maxabs.iter().map(|&m| m / 127.0).collect();
                let q = values
                    .iter()
                    .enumerate()
                    .map(|(idx, &v)| {
                        let s = scales[row_of(idx)];
                        if s == 0.0 {
                            0i8
                        } else {
                            (v / s).round().clamp(-127.0, 127.0) as i8
                        }
                    })
                    .collect();
                QVals::I8 { q, scales }
            }
        }
    }

    fn kind(&self) -> QuantKind {
        match self {
            QVals::F16(_) => QuantKind::F16,
            QVals::I8 { .. } => QuantKind::I8,
        }
    }

    fn len(&self) -> usize {
        match self {
            QVals::F16(v) => v.len(),
            QVals::I8 { q, .. } => q.len(),
        }
    }

    fn value_bytes(&self) -> usize {
        match self {
            QVals::F16(v) => v.len() * 2,
            QVals::I8 { q, scales } => q.len() + scales.len() * 4,
        }
    }

    /// Dequantise entry `idx` belonging to matrix row `row`.
    #[inline]
    fn get(&self, idx: usize, row: usize) -> f32 {
        match self {
            QVals::F16(v) => f16_bits_to_f32(v[idx]),
            QVals::I8 { q, scales } => q[idx] as f32 * scales[row],
        }
    }
}

/// CSR index structure with quantised values (decode/eval only).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantCsr {
    rows: usize,
    cols: usize,
    row_ptr: Vec<u32>,
    col_idx: Vec<u32>,
    vals: QVals,
}

impl QuantCsr {
    pub fn from_csr(csr: &CsrMatrix, kind: QuantKind) -> QuantCsr {
        let row_ptr = csr.row_ptr.clone();
        // entry -> matrix row, from the row pointers
        let mut entry_row = vec![0u32; csr.nnz()];
        for i in 0..csr.rows {
            for e in csr.row_ptr[i]..csr.row_ptr[i + 1] {
                entry_row[e as usize] = i as u32;
            }
        }
        let vals =
            QVals::quantise(&csr.values, kind, |idx| entry_row[idx] as usize, csr.rows.max(1));
        QuantCsr { rows: csr.rows, cols: csr.cols, row_ptr, col_idx: csr.col_idx.clone(), vals }
    }

    pub fn kind(&self) -> QuantKind {
        self.vals.kind()
    }
    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn value_bytes(&self) -> usize {
        self.vals.value_bytes()
    }

    pub fn mem_bytes(&self) -> usize {
        self.value_bytes() + self.col_idx.len() * 4 + self.row_ptr.len() * 4
    }

    /// Per-row i8 scales (empty for f16) — tests bound the round-trip
    /// error by `scale · 0.5`.
    pub fn scales(&self) -> &[f32] {
        match &self.vals {
            QVals::I8 { scales, .. } => scales,
            QVals::F16(_) => &[],
        }
    }

    /// Dequantise to dense — the *approximate* reconstruction.
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.rows {
            let lo = self.row_ptr[i] as usize;
            let hi = self.row_ptr[i + 1] as usize;
            for e in lo..hi {
                out[i * self.cols + self.col_idx[e] as usize] = self.vals.get(e, i);
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }

    /// Dot products for output columns `j0 .. j0+out.len()` of one
    /// activation row, dequantising in-register.
    pub fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        match &self.vals {
            QVals::F16(v) => {
                for (jj, o) in out.iter_mut().enumerate() {
                    let i = j0 + jj;
                    let lo = self.row_ptr[i] as usize;
                    let hi = self.row_ptr[i + 1] as usize;
                    let mut acc = 0.0f32;
                    for e in lo..hi {
                        acc += arow[self.col_idx[e] as usize] * f16_bits_to_f32(v[e]);
                    }
                    *o = acc;
                }
            }
            QVals::I8 { q, scales } => {
                for (jj, o) in out.iter_mut().enumerate() {
                    let i = j0 + jj;
                    let lo = self.row_ptr[i] as usize;
                    let hi = self.row_ptr[i + 1] as usize;
                    // factor the row scale out of the accumulation
                    let mut acc = 0.0f32;
                    for e in lo..hi {
                        acc += arow[self.col_idx[e] as usize] * q[e] as f32;
                    }
                    *o = acc * scales[i];
                }
            }
        }
    }

    /// Forward / decode contraction with in-register dequantisation.
    pub fn spmm_nt(&self, a: &Tensor) -> Tensor {
        let (n, k) = (a.rows(), a.cols());
        assert_eq!(k, self.cols, "qcsr spmm_nt inner-dim mismatch {k} vs {}", self.cols);
        let m = self.rows;
        let mut out = pool::zeroed(n * m);
        let ad = a.data();
        if n == 1 {
            out.par_chunks_mut(COLS_PER_TASK).enumerate().for_each(|(cj, chunk)| {
                self.dots_range(ad, cj * COLS_PER_TASK, chunk);
            });
        } else {
            out.par_chunks_mut(ROWS_PER_TASK * m).enumerate().for_each(|(ci, chunk)| {
                let i0 = ci * ROWS_PER_TASK;
                for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                    let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                    self.dots_range(arow, 0, orow);
                }
            });
        }
        Tensor::new(&[n, m], out)
    }
}

/// BSR index structure with quantised tile values (decode/eval only).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantBsr {
    rows: usize,
    cols: usize,
    br: usize,
    bc: usize,
    row_ptr: Vec<u32>,
    block_col: Vec<u32>,
    full: Vec<bool>,
    vals: QVals,
}

impl QuantBsr {
    pub fn from_bsr(bsr: &BsrMatrix, kind: QuantKind) -> QuantBsr {
        let (br, bc) = (bsr.br, bsr.bc);
        // tile entry -> matrix row
        let mut entry_row = vec![0u32; bsr.values.len()];
        for bi in 0..bsr.full.len() {
            let lo = bsr.row_ptr[bi] as usize;
            let hi = bsr.row_ptr[bi + 1] as usize;
            for b in lo..hi {
                for rr in 0..br {
                    let row = (bi * br + rr).min(bsr.rows.saturating_sub(1));
                    for t in 0..bc {
                        entry_row[b * br * bc + rr * bc + t] = row as u32;
                    }
                }
            }
        }
        let vals =
            QVals::quantise(&bsr.values, kind, |idx| entry_row[idx] as usize, bsr.rows.max(1));
        QuantBsr {
            rows: bsr.rows,
            cols: bsr.cols,
            br,
            bc,
            row_ptr: bsr.row_ptr.clone(),
            block_col: bsr.block_col.clone(),
            full: bsr.full.clone(),
            vals,
        }
    }

    pub fn kind(&self) -> QuantKind {
        self.vals.kind()
    }
    pub fn rows(&self) -> usize {
        self.rows
    }
    pub fn cols(&self) -> usize {
        self.cols
    }
    pub fn block_shape(&self) -> (usize, usize) {
        (self.br, self.bc)
    }

    pub fn value_bytes(&self) -> usize {
        self.vals.value_bytes()
    }

    pub fn mem_bytes(&self) -> usize {
        self.value_bytes() + self.block_col.len() * 4 + self.row_ptr.len() * 4
    }

    /// Dequantise to dense — the *approximate* reconstruction.
    pub fn to_dense(&self) -> Tensor {
        let (br, bc) = (self.br, self.bc);
        let mut out = vec![0.0f32; self.rows * self.cols];
        for bi in 0..self.full.len() {
            let lo = self.row_ptr[bi] as usize;
            let hi = self.row_ptr[bi + 1] as usize;
            for b in lo..hi {
                let bj = self.block_col[b] as usize;
                for rr in 0..br.min(self.rows - bi * br) {
                    for t in 0..bc.min(self.cols - bj * bc) {
                        out[(bi * br + rr) * self.cols + bj * bc + t] =
                            self.vals.get(b * br * bc + rr * bc + t, bi * br + rr);
                    }
                }
            }
        }
        Tensor::new(&[self.rows, self.cols], out)
    }

    /// Dot products for output columns `j0 .. j0+out.len()` of one
    /// activation row, dequantising in-register.
    pub fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        let (br, bc) = (self.br, self.bc);
        for (jj, o) in out.iter_mut().enumerate() {
            let i = j0 + jj;
            let (bi, rr) = (i / br, i % br);
            let lo = self.row_ptr[bi] as usize;
            let hi = self.row_ptr[bi + 1] as usize;
            let mut acc = 0.0f32;
            match &self.vals {
                QVals::F16(v) => {
                    for b in lo..hi {
                        let cb = self.block_col[b] as usize * bc;
                        let width = bc.min(self.cols - cb);
                        let base = b * br * bc + rr * bc;
                        for t in 0..width {
                            acc += arow[cb + t] * f16_bits_to_f32(v[base + t]);
                        }
                    }
                    *o = acc;
                }
                QVals::I8 { q, scales } => {
                    for b in lo..hi {
                        let cb = self.block_col[b] as usize * bc;
                        let width = bc.min(self.cols - cb);
                        let base = b * br * bc + rr * bc;
                        for t in 0..width {
                            acc += arow[cb + t] * q[base + t] as f32;
                        }
                    }
                    *o = acc * scales[i];
                }
            }
        }
    }

    /// Forward / decode contraction with in-register dequantisation.
    pub fn spmm_nt(&self, a: &Tensor) -> Tensor {
        let (n, k) = (a.rows(), a.cols());
        assert_eq!(k, self.cols, "qbsr spmm_nt inner-dim mismatch {k} vs {}", self.cols);
        let m = self.rows;
        let mut out = pool::zeroed(n * m);
        let ad = a.data();
        if n == 1 {
            out.par_chunks_mut(COLS_PER_TASK).enumerate().for_each(|(cj, chunk)| {
                self.dots_range(ad, cj * COLS_PER_TASK, chunk);
            });
        } else {
            out.par_chunks_mut(ROWS_PER_TASK * m).enumerate().for_each(|(ci, chunk)| {
                let i0 = ci * ROWS_PER_TASK;
                for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                    let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                    self.dots_range(arow, 0, orow);
                }
            });
        }
        Tensor::new(&[n, m], out)
    }
}

// ---------------------------------------------------------------------------
// SpMM kernels (CSR free functions — the PR 4 public surface).
// ---------------------------------------------------------------------------

/// Rows of `a` each rayon task owns in the tall-activation strategy.
const ROWS_PER_TASK: usize = 4;
/// Output columns per task in the single-row (decode) strategy.  A
/// multiple of every supported block height, so BSR chunks stay aligned.
const COLS_PER_TASK: usize = 64;

#[inline]
fn csr_dot(arow: &[f32], cols: &[u32], vals: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&c, &v) in cols.iter().zip(vals) {
        acc += arow[c as usize] * v;
    }
    acc
}

/// `a:(n,k) @ W:(m,k)ᵀ -> (n,m)` with `W` compressed — the forward /
/// decode contraction.  Only the `nnz` surviving weights are read, so the
/// weight-side memory traffic shrinks by `1 / (1 - sparsity)`.  Per output
/// element the accumulation order is ascending column index — identical to
/// `matmul_nt_masked`, so the two layouts agree bit-for-bit wherever no
/// stored weight is exactly zero.
pub fn spmm_nt(a: &Tensor, w: &CsrMatrix) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    assert_eq!(k, w.cols, "spmm_nt inner-dim mismatch {k} vs {}", w.cols);
    let m = w.rows;
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    if n == 1 {
        // one activation row (serve decode): parallelise over W rows instead
        out.par_chunks_mut(COLS_PER_TASK).enumerate().for_each(|(cj, chunk)| {
            w.dots_range(ad, cj * COLS_PER_TASK, chunk);
        });
    } else {
        out.par_chunks_mut(ROWS_PER_TASK * m).enumerate().for_each(|(ci, chunk)| {
            let i0 = ci * ROWS_PER_TASK;
            for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                w.dots_range(arow, 0, orow);
            }
        });
    }
    Tensor::new(&[n, m], out)
}

/// `a:(n,m) @ W:(m,k) -> (n,k)` with `W` compressed — the backward-dx
/// contraction.  Exact zeros of `a` are skipped (like `matmul`), and each
/// consumed `a` element scatters one compressed row; per output element
/// contributions arrive in ascending inner index, matching
/// `matmul_masked`'s order.
pub fn spmm(a: &Tensor, w: &CsrMatrix) -> Tensor {
    let (n, m) = (a.rows(), a.cols());
    assert_eq!(m, w.rows, "spmm inner-dim mismatch {m} vs {}", w.rows);
    let k = w.cols;
    let mut out = pool::zeroed(n * k);
    let ad = a.data();
    out.par_chunks_mut(ROWS_PER_TASK * k).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * ROWS_PER_TASK;
        for (ii, orow) in chunk.chunks_mut(k).enumerate() {
            let arow = &ad[(i0 + ii) * m..(i0 + ii + 1) * m];
            for (j, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let (cols, vals) = w.row(j);
                for (&c, &v) in cols.iter().zip(vals) {
                    orow[c as usize] += av * v;
                }
            }
        }
    });
    Tensor::new(&[n, k], out)
}

// ---------------------------------------------------------------------------
// Unified compressed form.
// ---------------------------------------------------------------------------

/// One compressed representation of a weight — what [`SparseStore`] caches
/// per layer and the kernels dispatch on.
#[derive(Debug, Clone, PartialEq)]
pub enum SparseForm {
    Csr(CsrMatrix),
    Bsr(BsrMatrix),
    QCsr(QuantCsr),
    QBsr(QuantBsr),
}

impl SparseForm {
    /// Build the form a resolved layout calls for (`None` for the
    /// uncompressed dense/masked layouts).  `structured` picks the native
    /// BSR block shape.
    pub fn build(
        layout: WeightLayout,
        w: &Tensor,
        mask: &Tensor,
        structured: bool,
    ) -> Option<SparseForm> {
        let bsr = || {
            let (br, bc) = BsrMatrix::native_block(structured);
            BsrMatrix::from_dense_masked(w, mask, br, bc)
        };
        match layout {
            WeightLayout::Dense | WeightLayout::Masked => None,
            WeightLayout::Csr => Some(SparseForm::Csr(CsrMatrix::from_dense_masked(w, mask))),
            WeightLayout::Bsr => Some(SparseForm::Bsr(bsr())),
            WeightLayout::CsrF16 => Some(SparseForm::QCsr(QuantCsr::from_csr(
                &CsrMatrix::from_dense_masked(w, mask),
                QuantKind::F16,
            ))),
            WeightLayout::CsrQ8 => Some(SparseForm::QCsr(QuantCsr::from_csr(
                &CsrMatrix::from_dense_masked(w, mask),
                QuantKind::I8,
            ))),
            WeightLayout::BsrF16 => {
                Some(SparseForm::QBsr(QuantBsr::from_bsr(&bsr(), QuantKind::F16)))
            }
            WeightLayout::BsrQ8 => {
                Some(SparseForm::QBsr(QuantBsr::from_bsr(&bsr(), QuantKind::I8)))
            }
        }
    }

    /// The layout this form executes as.
    pub fn layout(&self) -> WeightLayout {
        match self {
            SparseForm::Csr(_) => WeightLayout::Csr,
            SparseForm::Bsr(_) => WeightLayout::Bsr,
            SparseForm::QCsr(q) => match q.kind() {
                QuantKind::F16 => WeightLayout::CsrF16,
                QuantKind::I8 => WeightLayout::CsrQ8,
            },
            SparseForm::QBsr(q) => match q.kind() {
                QuantKind::F16 => WeightLayout::BsrF16,
                QuantKind::I8 => WeightLayout::BsrQ8,
            },
        }
    }

    pub fn mem_bytes(&self) -> usize {
        match self {
            SparseForm::Csr(c) => c.mem_bytes(),
            SparseForm::Bsr(b) => b.mem_bytes(),
            SparseForm::QCsr(q) => q.mem_bytes(),
            SparseForm::QBsr(q) => q.mem_bytes(),
        }
    }

    pub fn value_bytes(&self) -> usize {
        match self {
            SparseForm::Csr(c) => c.value_bytes(),
            SparseForm::Bsr(b) => b.value_bytes(),
            SparseForm::QCsr(q) => q.value_bytes(),
            SparseForm::QBsr(q) => q.value_bytes(),
        }
    }

    /// Decompress (exact for CSR/BSR, approximate for quantised forms).
    pub fn to_dense(&self) -> Tensor {
        match self {
            SparseForm::Csr(c) => c.to_dense(),
            SparseForm::Bsr(b) => b.to_dense(),
            SparseForm::QCsr(q) => q.to_dense(),
            SparseForm::QBsr(q) => q.to_dense(),
        }
    }

    /// Forward / decode contraction `a:(n,k) @ Wᵀ`.
    pub fn spmm_nt(&self, a: &Tensor) -> Tensor {
        match self {
            SparseForm::Csr(c) => spmm_nt(a, c),
            SparseForm::Bsr(b) => b.spmm_nt(a),
            SparseForm::QCsr(q) => q.spmm_nt(a),
            SparseForm::QBsr(q) => q.spmm_nt(a),
        }
    }

    /// Backward-dx contraction `a:(n,m) @ W` — exact forms only.
    /// Quantised forms return `None`: gradients must never be approximate,
    /// so callers fall back to the exact masked kernel.
    pub fn spmm(&self, a: &Tensor) -> Option<Tensor> {
        match self {
            SparseForm::Csr(c) => Some(spmm(a, c)),
            SparseForm::Bsr(b) => Some(b.spmm(a)),
            SparseForm::QCsr(_) | SparseForm::QBsr(_) => None,
        }
    }

    /// Dot products for output columns `j0 .. j0+out.len()` of one
    /// activation row — the shared unit the fused q/k/v decode kernel
    /// dispatches on per head run.
    pub fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        match self {
            SparseForm::Csr(c) => c.dots_range(arow, j0, out),
            SparseForm::Bsr(b) => b.dots_range(arow, j0, out),
            SparseForm::QCsr(q) => q.dots_range(arow, j0, out),
            SparseForm::QBsr(q) => q.dots_range(arow, j0, out),
        }
    }
}

// ---------------------------------------------------------------------------
// Named collections: the coordinator-side cache and its borrowed view.
// ---------------------------------------------------------------------------

/// Cached sparse state for a model's prunable linears: one resolved
/// [`WeightLayout`] per weight, plus the compressed [`SparseForm`]s for the
/// layers routed away from the dense/masked paths.  Built once per
/// weight/mask change (prune, merge, checkpoint load) so steady-state
/// train/serve loops never re-compress.
#[derive(Debug, Clone, Default)]
pub struct SparseStore {
    pub layouts: BTreeMap<String, WeightLayout>,
    pub forms: BTreeMap<String, SparseForm>,
}

impl SparseStore {
    /// Resolve a layout per layer from its measured `W⊙M` sparsity /
    /// structure and compress the routed layers.
    pub fn build<'a>(
        policy: LayoutPolicy,
        layers: impl Iterator<Item = (String, &'a Tensor, &'a Tensor)>,
    ) -> SparseStore {
        let mut store = SparseStore::default();
        store.update(policy, layers);
        store
    }

    /// Re-resolve and recompress a subset of layers in place — the cheap
    /// path when only one block's weights/masks changed (layer-wise
    /// reconstruction); [`SparseStore::build`] is `update` over everything.
    pub fn update<'a>(
        &mut self,
        policy: LayoutPolicy,
        layers: impl Iterator<Item = (String, &'a Tensor, &'a Tensor)>,
    ) {
        for (name, w, mask) in layers {
            let (layout, structured) = match policy {
                // fixed non-BSR policies never read the sparsity — skip the scan
                LayoutPolicy::Fixed(l)
                    if l.exact_counterpart() != WeightLayout::Bsr =>
                {
                    (l, false)
                }
                _ => {
                    let structured = is_nm_structured(w, mask, 2, 4);
                    let layout = match policy {
                        LayoutPolicy::Fixed(l) => l,
                        _ => {
                            let nnz = w
                                .data()
                                .iter()
                                .zip(mask.data())
                                .filter(|(&wv, &mv)| wv * mv != 0.0)
                                .count();
                            policy.resolve(1.0 - nnz as f64 / w.numel().max(1) as f64, structured)
                        }
                    };
                    (layout, structured)
                }
            };
            match SparseForm::build(layout, w, mask, structured) {
                Some(form) => {
                    self.forms.insert(name.clone(), form);
                }
                None => {
                    self.forms.remove(&name);
                }
            }
            self.layouts.insert(name, layout);
        }
    }

    /// No layer deviates from the default fused-masked path.
    pub fn is_empty(&self) -> bool {
        self.layouts.values().all(|l| *l == WeightLayout::Masked)
    }

    pub fn has_form(&self, name: &str) -> bool {
        self.forms.contains_key(name)
    }

    /// Total compressed bytes across layers (exported by the serve layer
    /// as the `perp_serve_sparse_weight_bytes` gauge).
    pub fn compressed_bytes(&self) -> usize {
        self.forms.values().map(SparseForm::mem_bytes).sum()
    }

    pub fn view(&self) -> SparseView<'_> {
        SparseView {
            layouts: self.layouts.clone(),
            forms: self.forms.iter().map(|(n, f)| (n.clone(), f)).collect(),
        }
    }
}

/// Borrowed per-execution view — what [`crate::runtime::Feed`] transports
/// and the native graph dispatches on.  An empty view means every linear
/// runs the fused masked kernels (the status quo).
#[derive(Debug, Default)]
pub struct SparseView<'a> {
    pub layouts: BTreeMap<String, WeightLayout>,
    pub forms: BTreeMap<String, &'a SparseForm>,
}

impl<'a> SparseView<'a> {
    /// Resolved layout for one weight; a compressed layout only when the
    /// form is actually present, so a stale routing can never panic the
    /// kernels.
    pub fn layout_of(&self, wname: &str) -> WeightLayout {
        if let Some(form) = self.forms.get(wname) {
            return form.layout();
        }
        match self.layouts.get(wname) {
            Some(WeightLayout::Dense) => WeightLayout::Dense,
            _ => WeightLayout::Masked,
        }
    }

    pub fn get_form(&self, wname: &str) -> Option<&'a SparseForm> {
        self.forms.get(wname).copied()
    }

    /// The CSR form, when that is what's cached (compat shim for callers
    /// that only understand CSR).
    pub fn get_csr(&self, wname: &str) -> Option<&'a CsrMatrix> {
        match self.forms.get(wname) {
            Some(SparseForm::Csr(c)) => Some(c),
            _ => None,
        }
    }
}

/// A binary mask with an exact number of zeros — benches and tests need
/// pinned sparsity levels, which thresholded gaussians only approximate.
pub fn random_mask(shape: &[usize], sparsity: f64, rng: &mut Rng) -> Tensor {
    let n: usize = shape.iter().product();
    let zeros = ((n as f64) * sparsity).round() as usize;
    let mut idx: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut idx);
    let mut data = vec![1.0f32; n];
    for &i in &idx[..zeros.min(n)] {
        data[i as usize] = 0.0;
    }
    Tensor::new(shape, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;

    fn random_case(m: usize, k: usize, sparsity: f64, seed: u64) -> (Tensor, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[m, k], 1.0, &mut rng);
        let mask = random_mask(&[m, k], sparsity, &mut rng);
        (w, mask)
    }

    /// A 2:4 semi-structured mask: exactly two survivors per aligned group
    /// of four columns.
    fn nm24_mask(m: usize, k: usize, rng: &mut Rng) -> Tensor {
        assert_eq!(k % 4, 0);
        let mut data = vec![0.0f32; m * k];
        for i in 0..m {
            for g in (0..k).step_by(4) {
                let mut picks = [0u32, 1, 2, 3];
                rng.shuffle(&mut picks);
                data[i * k + g + picks[0] as usize] = 1.0;
                data[i * k + g + picks[1] as usize] = 1.0;
            }
        }
        Tensor::new(&[m, k], data)
    }

    #[test]
    fn roundtrip_matches_masked_product() {
        for (m, k, s) in [(1usize, 1usize, 0.0), (7, 13, 0.5), (33, 65, 0.99), (8, 8, 1.0)] {
            let (w, mask) = random_case(m, k, s, 3);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            assert_eq!(csr.to_dense(), w.hadamard(&mask), "{m}x{k}@{s}");
            assert_eq!(csr.sparsity(), 1.0 - csr.nnz() as f64 / (m * k) as f64);
        }
    }

    #[test]
    fn all_ones_mask_compresses_weight_zeros() {
        // checkpoint serving: zeros live in the weights, the mask is dense
        let w = Tensor::new(&[2, 3], vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let ones = Tensor::ones(&[2, 3]);
        let csr = CsrMatrix::from_dense_masked(&w, &ones);
        assert_eq!(csr.nnz(), 3);
        assert_eq!(csr.to_dense(), w);
    }

    #[test]
    fn memory_formula() {
        let (w, mask) = random_case(16, 32, 0.9, 5);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        assert_eq!(csr.mem_bytes(), csr.nnz() * 8 + (16 + 1) * 4);
        assert_eq!(csr.value_bytes(), csr.nnz() * 4);
        assert_eq!(csr.dense_bytes(), 16 * 32 * 4);
        assert!(csr.mem_bytes() < csr.dense_bytes());
    }

    #[test]
    fn spmm_nt_bitwise_matches_masked_kernel() {
        let mut rng = Rng::new(11);
        for (n, k, m, s) in
            [(1usize, 33usize, 17usize, 0.9), (5, 64, 31, 0.5), (9, 17, 65, 0.0), (4, 8, 8, 1.0)]
        {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            let got = spmm_nt(&a, &csr);
            let want = linalg::matmul_nt_masked(&a, &w, &mask);
            assert_eq!(got.shape(), want.shape());
            for (x, y) in got.data().iter().zip(want.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{n}x{k}x{m}@{s}");
            }
        }
    }

    #[test]
    fn bsr_roundtrip_blocks_and_memory() {
        let mut rng = Rng::new(29);
        // ragged both ways: rows % br != 0, cols % bc != 0
        for (m, k, br, bc, s) in [
            (7usize, 13usize, 4usize, 4usize, 0.5),
            (16, 32, 4, 4, 0.9),
            (5, 12, 1, 4, 0.5),
            (9, 10, 2, 3, 0.7),
        ] {
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let bsr = BsrMatrix::from_dense_masked(&w, &mask, br, bc);
            assert_eq!(bsr.to_dense(), w.hadamard(&mask), "{m}x{k} {br}x{bc}@{s}");
            assert_eq!(bsr.block_shape(), (br, bc));
            assert_eq!(bsr.value_bytes(), bsr.n_blocks() * br * bc * 4);
            assert_eq!(
                bsr.mem_bytes(),
                bsr.value_bytes() + bsr.n_blocks() * 4 + (m.div_ceil(br) + 1) * 4
            );
        }
    }

    #[test]
    fn bsr_spmm_nt_bitwise_matches_masked_kernel() {
        let mut rng = Rng::new(31);
        // unstructured masks at 4x4 and 1x4 blocks, ragged dims, n==1 and n>1
        for (n, k, m, s) in
            [(1usize, 33usize, 17usize, 0.9), (5, 64, 31, 0.5), (9, 17, 65, 0.0), (4, 8, 8, 1.0)]
        {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let want = linalg::matmul_nt_masked(&a, &w, &mask);
            for (br, bc) in [(4usize, 4usize), (1, 4), (2, 3)] {
                let bsr = BsrMatrix::from_dense_masked(&w, &mask, br, bc);
                let got = bsr.spmm_nt(&a);
                assert_eq!(got.shape(), want.shape());
                for (x, y) in got.data().iter().zip(want.data()) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{n}x{k}x{m}@{s} {br}x{bc}");
                }
            }
        }
        // the 2:4 hot path: 1x4 blocks, every block row full -> lockstep
        for (n, k, m) in [(1usize, 64usize, 96usize), (3, 32, 48)] {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = nm24_mask(m, k, &mut rng);
            assert!(is_nm_structured(&w, &mask, 2, 4));
            let bsr = BsrMatrix::from_dense_masked(&w, &mask, 1, 4);
            let want = linalg::matmul_nt_masked(&a, &w, &mask);
            for (x, y) in bsr.spmm_nt(&a).data().iter().zip(want.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "2:4 {n}x{k}x{m}");
            }
        }
    }

    #[test]
    fn bsr_spmm_matches_masked_backward() {
        let mut rng = Rng::new(37);
        for (n, m, k, s) in [(1usize, 17usize, 33usize, 0.9), (6, 31, 64, 0.5), (3, 8, 8, 1.0)] {
            let dy = Tensor::randn(&[n, m], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let want = linalg::matmul_masked(&dy, &w, &mask);
            for (br, bc) in [(4usize, 4usize), (1, 4)] {
                let bsr = BsrMatrix::from_dense_masked(&w, &mask, br, bc);
                assert!(bsr.spmm(&dy).allclose(&want, 1e-6, 1e-6), "{n}x{m}x{k}@{s} {br}x{bc}");
            }
        }
    }

    #[test]
    fn bsr_empty_block_rows_and_ragged_tails() {
        // block row 0 fully pruned; rows not a multiple of br
        let w = Tensor::new(&[3, 5], vec![1.0; 15]);
        let mut md = vec![0.0f32; 15];
        md[1 * 5 + 2] = 1.0; // only row 1, col 2 survives
        let mask = Tensor::new(&[3, 5], md);
        let bsr = BsrMatrix::from_dense_masked(&w, &mask, 2, 4);
        assert_eq!(bsr.to_dense(), w.hadamard(&mask));
        let a = Tensor::new(&[1, 5], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(bsr.spmm_nt(&a).data(), &[0.0, 3.0, 0.0]);

        // fully pruned matrix stores no blocks at all
        let dead = BsrMatrix::from_dense_masked(&w, &Tensor::zeros(&[3, 5]), 2, 4);
        assert_eq!(dead.n_blocks(), 0);
        assert_eq!(dead.spmm_nt(&a).data(), &[0.0; 3]);
        assert_eq!(dead.spmm(&Tensor::ones(&[2, 3])).data(), &[0.0; 10]);

        // single row, single partial block
        let single = BsrMatrix::from_dense_masked(
            &Tensor::new(&[1, 3], vec![2.0, 0.0, 4.0]),
            &Tensor::ones(&[1, 3]),
            1,
            4,
        );
        assert_eq!(single.n_blocks(), 1);
        assert_eq!(single.spmm_nt(&Tensor::new(&[1, 3], vec![1.0, 1.0, 1.0])).data(), &[6.0]);
    }

    #[test]
    fn spmm_matches_masked_backward() {
        let mut rng = Rng::new(13);
        for (n, m, k, s) in [(1usize, 17usize, 33usize, 0.9), (6, 31, 64, 0.5), (3, 8, 8, 1.0)] {
            let dy = Tensor::randn(&[n, m], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = random_mask(&[m, k], s, &mut rng);
            let csr = CsrMatrix::from_dense_masked(&w, &mask);
            let got = spmm(&dy, &csr);
            let want = linalg::matmul_masked(&dy, &w, &mask);
            assert!(got.allclose(&want, 1e-6, 1e-6), "{n}x{m}x{k}@{s}");
        }
    }

    #[test]
    fn empty_and_single_rows() {
        // row 0 fully pruned, single-row matrix, fully pruned matrix
        let w = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mask = Tensor::new(&[2, 3], vec![0.0, 0.0, 0.0, 1.0, 0.0, 1.0]);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        let a = Tensor::new(&[1, 3], vec![1.0, 1.0, 1.0]);
        assert_eq!(spmm_nt(&a, &csr).data(), &[0.0, 10.0]);

        let single = CsrMatrix::from_dense_masked(
            &Tensor::new(&[1, 3], vec![2.0, 0.0, 4.0]),
            &Tensor::ones(&[1, 3]),
        );
        assert_eq!(spmm_nt(&a, &single).data(), &[6.0]);
        assert_eq!(single.row(0).0, &[0, 2]);

        let dead = CsrMatrix::from_dense_masked(&w, &Tensor::zeros(&[2, 3]));
        assert_eq!(dead.nnz(), 0);
        assert_eq!(spmm_nt(&a, &dead).data(), &[0.0, 0.0]);
        assert_eq!(spmm(&Tensor::ones(&[2, 2]), &dead).data(), &[0.0; 6]);
    }

    #[test]
    fn f16_bits_exhaustive_roundtrip() {
        // every non-NaN f16 pattern survives f16 -> f32 -> f16 exactly
        for h in 0..=u16::MAX {
            let e = (h >> 10) & 0x1f;
            let man = h & 0x3ff;
            if e == 0x1f && man != 0 {
                assert!(f16_bits_to_f32(h).is_nan());
                continue;
            }
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "pattern {h:#06x}");
        }
        // overflow saturates instead of producing inf
        assert_eq!(f32_to_f16_bits(1e6), 0x7bff);
        assert_eq!(f32_to_f16_bits(-1e6), 0xfbff);
        assert_eq!(f16_bits_to_f32(0x7bff), 65504.0);
    }

    #[test]
    fn quant_i8_roundtrip_error_within_half_scale() {
        let mut rng = Rng::new(41);
        let (w, mask) = random_case(24, 40, 0.6, 43);
        let exact = w.hadamard(&mask);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        let q = QuantCsr::from_csr(&csr, QuantKind::I8);
        assert_eq!(q.scales().len(), 24);
        let dq = q.to_dense();
        for i in 0..24 {
            let bound = q.scales()[i] * 0.5 + 1e-6;
            for j in 0..40 {
                let err = (dq.data()[i * 40 + j] - exact.data()[i * 40 + j]).abs();
                assert!(err <= bound, "row {i}: err {err} > scale/2 {bound}");
            }
        }
        // BSR variant: same per-matrix-row bound
        let bsr = BsrMatrix::from_dense_masked(&w, &mask, 4, 4);
        let qb = QuantBsr::from_bsr(&bsr, QuantKind::I8);
        let dqb = qb.to_dense();
        for i in 0..24 {
            let bound = q.scales()[i] * 0.5 + 1e-6;
            for j in 0..40 {
                let err = (dqb.data()[i * 40 + j] - exact.data()[i * 40 + j]).abs();
                assert!(err <= bound, "bsr row {i}: err {err} > {bound}");
            }
        }
        // f16 variant: relative error within 2^-11 (plus tiny absolute slack)
        let qf = QuantCsr::from_csr(&csr, QuantKind::F16);
        let dqf = qf.to_dense();
        for (x, y) in dqf.data().iter().zip(exact.data()) {
            assert!((x - y).abs() <= y.abs() * 4.9e-4 + 1e-7, "f16 {x} vs {y}");
        }
        // quantised spmm stays close to the exact contraction
        let a = Tensor::randn(&[3, 40], 1.0, &mut rng);
        let want = linalg::matmul_nt_masked(&a, &w, &mask);
        assert!(qf.spmm_nt(&a).allclose(&want, 1e-2, 1e-2));
        assert!(q.spmm_nt(&a).allclose(&want, 0.2, 0.2));
        assert!(qb.spmm_nt(&a).allclose(&want, 0.2, 0.2));
    }

    #[test]
    fn quant_value_bytes_shrink() {
        let (w, mask) = random_case(64, 64, 0.7, 47);
        let csr = CsrMatrix::from_dense_masked(&w, &mask);
        let q8 = QuantCsr::from_csr(&csr, QuantKind::I8);
        let f16 = QuantCsr::from_csr(&csr, QuantKind::F16);
        // i8 + per-row scales: <= 0.55x the f32 value bytes (the
        // acceptance bound); f16 exactly half
        assert!(
            (q8.value_bytes() as f64) <= 0.55 * csr.value_bytes() as f64,
            "q8 {} vs csr {}",
            q8.value_bytes(),
            csr.value_bytes()
        );
        assert_eq!(f16.value_bytes(), csr.value_bytes() / 2);
    }

    #[test]
    fn sparse_form_dispatch_and_dots_range() {
        let mut rng = Rng::new(53);
        let w = Tensor::randn(&[20, 16], 1.0, &mut rng);
        let mask = random_mask(&[20, 16], 0.6, &mut rng);
        let a = Tensor::randn(&[1, 16], 1.0, &mut rng);
        for layout in [
            WeightLayout::Csr,
            WeightLayout::Bsr,
            WeightLayout::CsrF16,
            WeightLayout::CsrQ8,
            WeightLayout::BsrF16,
            WeightLayout::BsrQ8,
        ] {
            let form = SparseForm::build(layout, &w, &mask, false).unwrap();
            assert_eq!(form.layout(), layout);
            let via_spmm = form.spmm_nt(&a);
            // dots_range in odd-sized chunks must agree bit-for-bit with
            // the full spmm (the fused-qkv contract)
            let mut out = vec![0.0f32; 20];
            let mut j0 = 0usize;
            for chunk in [7usize, 9, 4] {
                let hi = (j0 + chunk).min(20);
                form.dots_range(a.data(), j0, &mut out[j0..hi]);
                j0 = hi;
            }
            for (x, y) in out.iter().zip(via_spmm.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "{}", layout.name());
            }
            // backward only exists for exact forms
            assert_eq!(form.spmm(&a).is_some(), !layout.is_quantised());
            assert!(form.value_bytes() > 0 && form.mem_bytes() > form.value_bytes());
        }
        assert!(SparseForm::build(WeightLayout::Masked, &w, &mask, false).is_none());
        assert!(SparseForm::build(WeightLayout::Dense, &w, &mask, false).is_none());
    }

    #[test]
    fn nm_structure_probe() {
        let mut rng = Rng::new(59);
        let w = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let mask24 = nm24_mask(8, 16, &mut rng);
        assert!(is_nm_structured(&w, &mask24, 2, 4));
        assert!(!is_nm_structured(&w, &Tensor::ones(&[8, 16]), 2, 4));
        // cols not divisible by the group size
        let w5 = Tensor::randn(&[4, 5], 1.0, &mut rng);
        assert!(!is_nm_structured(&w5, &Tensor::zeros(&[4, 5]), 2, 4));
        // all-pruned is trivially structured
        assert!(is_nm_structured(&w, &Tensor::zeros(&[8, 16]), 2, 4));
        assert_eq!(BsrMatrix::native_block(true), (1, 4));
        assert_eq!(BsrMatrix::native_block(false), (4, 4));
    }

    #[test]
    fn policy_parse_and_resolve() {
        assert_eq!(LayoutPolicy::parse("auto").unwrap(), LayoutPolicy::Auto);
        assert_eq!(LayoutPolicy::parse("auto-q").unwrap(), LayoutPolicy::AutoQuant);
        assert_eq!(LayoutPolicy::parse("csr").unwrap(), LayoutPolicy::Fixed(WeightLayout::Csr));
        assert_eq!(LayoutPolicy::parse("bsr").unwrap(), LayoutPolicy::Fixed(WeightLayout::Bsr));
        assert_eq!(
            LayoutPolicy::parse("bsr-q8").unwrap(),
            LayoutPolicy::Fixed(WeightLayout::BsrQ8)
        );
        let err = LayoutPolicy::parse("coo").unwrap_err();
        assert!(err.contains("allowed:") && err.contains("bsr-q8"), "{err}");
        assert_eq!("csr-f16".parse::<LayoutPolicy>().unwrap().name(), "csr-f16");

        // fallback heuristic (no table): threshold + structure
        let none: Option<&CrossoverTable> = None;
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.99, false, none), WeightLayout::Csr);
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.0, false, none), WeightLayout::Masked);
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.5, true, none), WeightLayout::Bsr);
        assert_eq!(LayoutPolicy::AutoQuant.resolve_with(0.99, false, none), WeightLayout::CsrQ8);
        assert_eq!(LayoutPolicy::AutoQuant.resolve_with(0.5, true, none), WeightLayout::BsrQ8);
        assert_eq!(LayoutPolicy::AutoQuant.resolve_with(0.0, false, none), WeightLayout::Masked);
        assert_eq!(
            LayoutPolicy::Fixed(WeightLayout::Dense).resolve_with(0.99, false, none),
            WeightLayout::Dense
        );
        assert!(LayoutPolicy::AutoQuant.may_quantise());
        assert!(!LayoutPolicy::Auto.may_quantise());
        assert!(LayoutPolicy::Fixed(WeightLayout::BsrQ8).may_quantise());
        assert!(!LayoutPolicy::Fixed(WeightLayout::Bsr).may_quantise());
    }

    #[test]
    fn auto_dispatch_consumes_crossover_table_argmax() {
        // the measured table, not the threshold, decides: entries where the
        // heuristic would pick differently
        let json = Json::parse(
            r#"{"crossover":[
                {"sparsity":0.5,"pattern":"2:4","best_exact":"bsr","best_any":"bsr-q8"},
                {"sparsity":0.5,"pattern":"unstructured","best_exact":"masked","best_any":"masked"},
                {"sparsity":0.9,"pattern":"unstructured","best_exact":"csr","best_any":"csr-q8"},
                {"sparsity":0.95,"pattern":"unstructured","best_exact":"bsr","best_any":"bsr-q8"}
            ]}"#,
        )
        .unwrap();
        let table = CrossoverTable::from_json(&json).unwrap();
        assert_eq!(table.entries.len(), 4);
        let t = Some(&table);

        // argmax per operating point: nearest sparsity, matching structure
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.5, true, t), WeightLayout::Bsr);
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.55, false, t), WeightLayout::Masked);
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.88, false, t), WeightLayout::Csr);
        // 0.94 is nearest the 0.95 entry -> the table overrides the
        // hard-coded csr choice with the measured bsr win
        assert_eq!(LayoutPolicy::Auto.resolve_with(0.94, false, t), WeightLayout::Bsr);
        // plain auto stays exact even where best_any is quantised
        assert!(!LayoutPolicy::Auto.resolve_with(0.9, false, t).is_quantised());
        // auto-q takes the quantised argmax
        assert_eq!(LayoutPolicy::AutoQuant.resolve_with(0.9, false, t), WeightLayout::CsrQ8);
        assert_eq!(LayoutPolicy::AutoQuant.resolve_with(0.5, true, t), WeightLayout::BsrQ8);

        // a table claiming a quantised best_exact is rejected outright
        let bad = Json::parse(
            r#"{"crossover":[{"sparsity":0.9,"pattern":"unstructured","best_exact":"csr-q8"}]}"#,
        )
        .unwrap();
        assert!(CrossoverTable::from_json(&bad).is_err());
        // and a report with no crossover key is an error, not a panic
        assert!(CrossoverTable::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn store_builds_forms_only_where_routed() {
        let mut rng = Rng::new(17);
        let dense_w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let sparse_w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let ones = Tensor::ones(&[8, 8]);
        let mask = random_mask(&[8, 8], 0.9, &mut rng);
        let layers = vec![
            ("a_w".to_string(), &dense_w, &ones),
            ("b_w".to_string(), &sparse_w, &mask),
        ];
        let store = SparseStore::build(LayoutPolicy::Auto, layers.into_iter());
        assert_eq!(store.layouts["a_w"], WeightLayout::Masked);
        assert_eq!(store.layouts["b_w"], WeightLayout::Csr);
        assert!(store.has_form("b_w") && !store.has_form("a_w"));
        assert!(!store.is_empty());
        assert!(store.compressed_bytes() > 0);
        let view = store.view();
        assert_eq!(view.layout_of("a_w"), WeightLayout::Masked);
        assert_eq!(view.layout_of("b_w"), WeightLayout::Csr);
        assert_eq!(view.layout_of("unknown_w"), WeightLayout::Masked);
        assert!(view.get_form("b_w").is_some());
        assert!(view.get_csr("b_w").is_some());
    }

    #[test]
    fn store_routes_structured_masks_to_1x4_bsr() {
        let mut rng = Rng::new(61);
        let w = Tensor::randn(&[8, 16], 1.0, &mut rng);
        let mask = nm24_mask(8, 16, &mut rng);
        let store = SparseStore::build(
            LayoutPolicy::Auto,
            vec![("q_w".to_string(), &w, &mask)].into_iter(),
        );
        assert_eq!(store.layouts["q_w"], WeightLayout::Bsr);
        match &store.forms["q_w"] {
            SparseForm::Bsr(b) => assert_eq!(b.block_shape(), (1, 4)),
            other => panic!("expected bsr form, got {:?}", other.layout()),
        }
        // fixed bsr on an unstructured mask falls back to 4x4 tiles
        let um = random_mask(&[8, 16], 0.9, &mut rng);
        let fixed = SparseStore::build(
            LayoutPolicy::Fixed(WeightLayout::Bsr),
            vec![("u_w".to_string(), &w, &um)].into_iter(),
        );
        match &fixed.forms["u_w"] {
            SparseForm::Bsr(b) => assert_eq!(b.block_shape(), (4, 4)),
            other => panic!("expected bsr form, got {:?}", other.layout()),
        }
    }

    #[test]
    fn store_auto_quant_routes_quantised_forms() {
        let mut rng = Rng::new(67);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let mask = random_mask(&[8, 8], 0.9, &mut rng);
        let store = SparseStore::build(
            LayoutPolicy::AutoQuant,
            vec![("a_w".to_string(), &w, &mask)].into_iter(),
        );
        assert_eq!(store.layouts["a_w"], WeightLayout::CsrQ8);
        let view = store.view();
        assert_eq!(view.layout_of("a_w"), WeightLayout::CsrQ8);
        assert!(view.get_form("a_w").is_some());
        // the CSR compat accessor refuses to hand out a quantised form
        assert!(view.get_csr("a_w").is_none());
    }

    #[test]
    fn store_update_rescans_only_named_layers_and_drops_stale_forms() {
        let mut rng = Rng::new(23);
        let w = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let sparse_mask = random_mask(&[8, 8], 0.9, &mut rng);
        let ones = Tensor::ones(&[8, 8]);
        let mut store = SparseStore::build(
            LayoutPolicy::Auto,
            vec![("a_w".to_string(), &w, &sparse_mask)].into_iter(),
        );
        assert!(store.has_form("a_w"));
        // the layer went dense (e.g. reconstruction reset): the form must go away
        store.update(LayoutPolicy::Auto, vec![("a_w".to_string(), &w, &ones)].into_iter());
        assert!(!store.has_form("a_w"));
        assert_eq!(store.layouts["a_w"], WeightLayout::Masked);
        // and back to pruned: recompressed, other entries untouched
        store.update(
            LayoutPolicy::Auto,
            vec![("a_w".to_string(), &w, &sparse_mask)].into_iter(),
        );
        assert!(store.has_form("a_w"));
        assert_eq!(store.forms["a_w"].to_dense(), w.hadamard(&sparse_mask));
    }

    #[test]
    fn random_mask_hits_exact_sparsity() {
        let mut rng = Rng::new(19);
        let m = random_mask(&[40, 50], 0.95, &mut rng);
        assert_eq!(m.count(|x| x == 0.0), 1900);
    }
}
