//! Declarative pipeline plans: compose PERP's verbs instead of hard-wiring
//! one sequence per subcommand.
//!
//! * [`plan`] — the typed [`Stage`] enum and the [`Plan`] container with a
//!   builder API, JSON (de)serialization over [`crate::util::json`] and
//!   structural validation (`merge` needs a pending LoRA retrain, `retrain`
//!   needs masks, ...).
//! * [`parse`] — the inline `--stages` grammar:
//!   `"prune(wanda,0.5)|retrain(masklora,100)|merge|eval"`.
//! * [`cachekey`] — content addressing: every stage is keyed by an FNV-1a
//!   chain over (model, experiment config, seed, all upstream stage specs),
//!   so two plans sharing a prefix share its artifacts.
//! * [`executor`] — drives a [`Plan`] over a [`crate::coordinator::Session`],
//!   persisting per-stage artifacts (`state.ptns`, `masks.ptns`, adapters,
//!   `meta.json`) under `<cache>/plan/<key>/`.  Re-running a plan loads
//!   completed stages instead of recomputing them; `--force` ignores the
//!   stage cache (the keyed dense pretrain checkpoint is still reused — it
//!   is deterministic in the key inputs).
//!
//! The CLI subcommands (`repro pretrain/prune/retrain/reconstruct/eval`) are
//! thin shims over 1–3 distinctive stages each, `repro run` executes
//! arbitrary plan files, and the sweep registry generates plans for its
//! cells — one execution path for everything.

pub mod cachekey;
pub mod executor;
pub mod parse;
pub mod plan;

pub use executor::{EvalMetrics, Executor, RunReport, StageReport};
pub use plan::{Plan, Stage};
