//! End-to-end serving smoke: boot a real engine (dense gpt-nano, cached
//! pretrain), bind the HTTP server on an ephemeral port, and drive every
//! endpoint through the real TCP stack — including 8 concurrent
//! `/generate` streams through the dynamic batcher.

use std::sync::Arc;

use perp::config::ExperimentConfig;
use perp::server::{batcher, client, BatchCfg, EngineSpec, ServeState, Server};
use perp::util::json::Json;

fn quick_cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 60;
    c
}

#[test]
fn serve_endpoints_and_concurrent_streams() {
    let cache = std::env::temp_dir().join("perp_serve_smoke_cache");
    let state =
        Arc::new(ServeState::new("gpt-nano".to_string(), quick_cfg(), cache.clone(), 0));
    let engine = batcher::spawn(EngineSpec {
        name: "gpt-nano".to_string(),
        cfg: quick_cfg(),
        seed: 0,
        checkpoint: None,
        cache_dir: cache,
        batch: BatchCfg::default(),
        draft: None,
        spec_k: 0,
    })
    .unwrap();
    state.insert(engine).unwrap();
    let server = Server::bind(state, "127.0.0.1:0", 10).unwrap();
    let addr = server.addr;
    let handle = server.spawn();

    // health
    let (status, body) = client::get(addr, "/healthz").unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("\"status\":\"ok\""), "{body}");
    assert!(body.contains("gpt-nano"), "{body}");

    // model registry detail carries the KV memory facts
    let (status, body) = client::get(addr, "/models").unwrap();
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    let m = &j.req("models").as_arr().unwrap()[0];
    assert!(m.req("kv_cache_bytes").as_f64().unwrap() > 0.0);
    assert!(m.req("slots").as_usize().unwrap() >= 8);

    // 8 concurrent /generate streams through the dynamic batcher
    let results: Vec<(u16, Json)> = std::thread::scope(|sc| {
        let mut joins = Vec::new();
        for i in 0..8 {
            joins.push(sc.spawn(move || {
                let body = Json::obj(vec![
                    ("prompt", Json::Str(format!("the sample prompt number {i}"))),
                    ("max_tokens", Json::Num(6.0)),
                ]);
                client::post_json(addr, "/generate", &body).unwrap()
            }));
        }
        joins.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(results.len(), 8);
    for (status, j) in &results {
        assert_eq!(*status, 200, "{j}");
        let completion = j.req("completion").as_str().unwrap();
        assert!(!completion.is_empty(), "empty completion: {j}");
        assert!(!j.req("tokens").as_arr().unwrap().is_empty());
        assert_eq!(j.req("model").as_str().unwrap(), "gpt-nano");
    }

    // scoring returns a finite perplexity
    let (status, j) = client::post_json(
        addr,
        "/score",
        &Json::obj(vec![("text", Json::Str("the model the model the".to_string()))]),
    )
    .unwrap();
    assert_eq!(status, 200, "{j}");
    assert!(j.req("ppl").as_f64().unwrap() > 0.0);
    assert!(j.req("tokens").as_usize().unwrap() > 0);

    // metrics reflect the traffic we just generated
    let (status, text) = client::get(addr, "/metrics").unwrap();
    assert_eq!(status, 200);
    assert!(text.contains("perp_serve_decode_steps_total"), "{text}");
    assert!(
        text.contains("perp_serve_completed_total{model=\"gpt-nano\"} 8"),
        "{text}"
    );

    // error paths: unknown variant -> 404, bad json -> 400, no route -> 404
    let (status, _) = client::post_json(
        addr,
        "/generate",
        &Json::obj(vec![
            ("prompt", Json::Str("x".to_string())),
            ("model", Json::Str("nope".to_string())),
        ]),
    )
    .unwrap();
    assert_eq!(status, 404);
    let (status, _) = client::request(addr, "POST", "/generate", Some("{not json")).unwrap();
    assert_eq!(status, 400);
    let (status, _) = client::get(addr, "/nope").unwrap();
    assert_eq!(status, 404);

    handle.stop();
}
