//! Minimal JSON codec (serde replacement).
//!
//! Covers the full JSON grammar; used for the artifact manifest, experiment
//! configs and metrics logs.  Numbers are kept as f64 (the manifest only
//! contains shapes/scalars well inside f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ----- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Panicking accessor for required fields (manifest is machine-written).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?} in {self:.60?}"))
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or(default)
            .to_string()
    }

    // ----- constructors ---------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }
    pub fn arr_str(v: &[&str]) -> Json {
        Json::Arr(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ----- parse ----------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // ----- serialize ------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        // surrogate pairs
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("missing low surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char)
                                        .to_digit(16)
                                        .ok_or_else(|| self.err("bad hex"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                // SAFETY: we validate utf8 by construction — input is &str.
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf8: copy the raw bytes of this char
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let chunk = std::str::from_utf8(&self.b[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-12", "3.5", "1e3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{s}");
        }
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null}}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("x")
        );
        assert_eq!(v.req("c").req("d"), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\"Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\"Aé"));
        // serialize/parse roundtrip keeps content
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn surrogate_pairs() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo→"));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_format_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
    }
}
