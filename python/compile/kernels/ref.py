"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: each kernel's pytest suite sweeps
shapes/dtypes with hypothesis and asserts allclose against the function here.
They are also the semantic specification the rust-side property tests mirror
(rust/src/pruning, rust/src/peft re-implement the mask/merge algebra on host
tensors and are tested against fixtures generated from these definitions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Linear / LoRA forwards.  Weight convention: W has shape (out, in); the
# layer computes y = x @ W^T (+ bias handled by the caller).
# ---------------------------------------------------------------------------

def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """y = x @ W^T for x:(n,k), w:(m,k) -> (n,m)."""
    return x @ w.T


def masked_matmul(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """y = x @ (W*M)^T — the pruned-linear forward."""
    return x @ (w * mask).T


def lora_matmul(x, w, a, b, scale):
    """Standard LoRA: y = x @ W^T + scale * (x @ A^T) @ B^T.

    a: (r, in), b: (out, r). Exploits associativity — BA never materialised.
    """
    return x @ w.T + scale * ((x @ a.T) @ b.T)


def masked_lora_matmul(x, w, mask, a, b, scale):
    """MaskLoRA (PERP §3.2): y = x @ (W*M + M ⊙ (scale·B@A))^T.

    The Hadamard with M forces the adapter update to respect the sparsity
    pattern, which is what makes the merge W <- W*M + M⊙(s·BA) lossless.
    """
    z = w * mask + mask * (scale * (b @ a))
    return x @ z.T


def scale_lora_matmul(x, w, mask, a, b):
    """ScaleLoRA (PERP §3.2): y = x @ ((B@A) ⊙ (W*M))^T.

    Multiplicative adapters: zeros of W*M stay zero under the merge
    W <- (BA) ⊙ (W*M).  B,A are ones/sqrt(r)-initialised so BA == 1 at start.
    """
    z = (b @ a) * (w * mask)
    return x @ z.T


def masklora_merge(w, mask, a, b, scale):
    """Merged weight after MaskLoRA retraining."""
    return w * mask + mask * (scale * (b @ a))


def scalelora_merge(w, mask, a, b):
    """Merged weight after ScaleLoRA retraining."""
    return (b @ a) * (w * mask)


def lora_prune_merge(w, mask, a, b, scale):
    """LoRA-Prune: train unmasked LoRA, then apply the mask at merge time.

    This is the paper's strawman — re-pruning BA disrupts the model."""
    return (w + scale * (b @ a)) * mask


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------

def attention(q, k, v, causal: bool = True):
    """softmax(q k^T / sqrt(dh)) v per (batch, head).

    q,k,v: (B, H, S, dh).  Causal mask applied when ``causal``.
    """
    *_, s, dh = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        idx = jnp.arange(s)
        causal_mask = idx[:, None] >= idx[None, :]
        scores = jnp.where(causal_mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


# ---------------------------------------------------------------------------
# Normalisation.
# ---------------------------------------------------------------------------

def layernorm(x, scale, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def rmsnorm(x, scale, eps: float = 1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * scale


# ---------------------------------------------------------------------------
# Optimizer.
# ---------------------------------------------------------------------------

def adamw(p, g, m, v, step, lr, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0):
    """One AdamW step (decoupled weight decay).  ``step`` is 1-based."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**step)
    vhat = v2 / (1.0 - beta2**step)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2


# ---------------------------------------------------------------------------
# Pruning criteria (mask generation).
# ---------------------------------------------------------------------------

def magnitude_mask(w, sparsity: float):
    """Uniform per-tensor magnitude mask: zero the ``sparsity`` fraction of
    smallest-|w| entries.  Ties broken by flat index (matches rust impl)."""
    flat = jnp.abs(w).ravel()
    k = int(round(sparsity * flat.size))
    if k == 0:
        return jnp.ones_like(w)
    # kth smallest magnitude is the threshold; strictly-below is pruned,
    # ties at the threshold pruned by ascending flat index.
    order = jnp.argsort(flat, stable=True)
    mask = jnp.ones_like(flat)
    mask = mask.at[order[:k]].set(0.0)
    return mask.reshape(w.shape)


def semistructured_mask(w, n: int, m: int):
    """N:M mask along the input dim: in every group of ``m`` consecutive
    inputs keep the ``n`` largest |w|."""
    out, inp = w.shape
    assert inp % m == 0, (inp, m)
    groups = jnp.abs(w).reshape(out, inp // m, m)
    # rank within each group, descending magnitude; keep rank < n
    order = jnp.argsort(-groups, axis=-1, stable=True)
    ranks = jnp.argsort(order, axis=-1, stable=True)
    mask = (ranks < n).astype(w.dtype)
    return mask.reshape(out, inp)


def wanda_scores(w, x_norm):
    """Wanda score S_ij = |W_ij| * ||X_j||_2 (Sun et al. 2023).

    x_norm: (in,) — L2 norms of each input feature over the calibration set.
    """
    return jnp.abs(w) * x_norm[None, :]


def wanda_mask(w, x_norm, sparsity: float):
    """Per-output-row Wanda mask (comparison group = row, as in the paper)."""
    s = wanda_scores(w, x_norm)
    out, inp = w.shape
    k = int(round(sparsity * inp))
    if k == 0:
        return jnp.ones_like(w)
    order = jnp.argsort(s, axis=1, stable=True)
    ranks = jnp.argsort(order, axis=1, stable=True)
    return (ranks >= k).astype(w.dtype)


# ---------------------------------------------------------------------------
# Layer-wise reconstruction (PERP Eq. 1).
# ---------------------------------------------------------------------------

def reconstruction_loss(w0, w_hat, mask, x):
    """|| W0 X - (M ⊙ W_hat) X ||_F^2 / n  with X given row-major (n, in)."""
    y0 = x @ w0.T
    y1 = x @ (mask * w_hat).T
    return jnp.mean(jnp.square(y0 - y1)) * y0.shape[-1]


def masklora_reconstruction_loss(w0, w, mask, a, b, scale, x):
    """Eq. 1 with W_hat reparametrised through MaskLoRA adapters."""
    y0 = x @ w0.T
    y1 = masked_lora_matmul(x, w, mask, a, b, scale)
    return jnp.mean(jnp.square(y0 - y1)) * y0.shape[-1]
