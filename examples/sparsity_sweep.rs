//! Sparsity sweep (Fig 1 shape): perplexity vs sparsity for every retrained
//! parameter subset, printed as an aligned series.
//!
//! ```bash
//! cargo run --release --offline --example sparsity_sweep -- [--model gpt-nano]
//! ```

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::coordinator::sweep::ExpContext;
use perp::peft::Mode;
use perp::pruning::{Criterion, Pattern};
use perp::runtime::open_default_backend;
use perp::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).map_err(|e| anyhow::anyhow!(e))?;
    let model = args.str("model", "gpt-nano");
    let steps = args.u64("steps", 100)?;
    args.finish()?;

    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::quick(&model);
    cfg.pretrain_steps = 3000;
    let ctx = ExpContext::new(rt.as_ref(), cfg.clone(), "results/cache".into());

    let sparsities = [0.3, 0.4, 0.5, 0.6, 0.7];
    let methods: Vec<(&str, Option<Mode>)> = vec![
        ("no retraining", None),
        ("head", Some(Mode::Head)),
        ("embed", Some(Mode::Embed)),
        ("biases", Some(Mode::Biases)),
        ("ln", Some(Mode::Ln)),
        ("masklora", Some(Mode::MaskLora)),
        ("full ft", Some(Mode::Full)),
    ];

    print!("{:<16}", "method");
    for sp in sparsities {
        print!(" {:>8.0}%", sp * 100.0);
    }
    println!();

    for (label, mode) in methods {
        print!("{label:<16}");
        for sp in sparsities {
            let (base, _) =
                ctx.pruned_session(0, Criterion::Magnitude, Pattern::Unstructured(sp))?;
            let ppl = match mode {
                None => base.eval_ppl_test()?.ppl,
                Some(m) => {
                    let mut s = ctx.clone_session(&base)?;
                    s.retrain(m, steps, cfg.lr_grid[0])?;
                    s.merge_adapters()?;
                    s.eval_ppl_test()?.ppl
                }
            };
            print!(" {ppl:>9.2}");
        }
        println!();
    }
    Ok(())
}
