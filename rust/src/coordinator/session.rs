//! Session: all mutable experiment state plus the pipeline verbs.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::data::{tasks, Batcher, Corpus, CorpusConfig, Tokenizer};
use crate::eval::{self, PplResult, TaskResult};
use crate::metrics::TpsMeter;
use crate::model::{init, ParamStore};
use crate::optim::{OptState, Schedule};
use crate::peft::{merge, LoraState, Mode};
use crate::pruning::{magnitude, sparsegpt, wanda, Criterion, MaskSet, Pattern};
use crate::runtime::{Backend, Feed, ModelManifest};
use crate::tensor::sparse::{LayoutPolicy, SparseStore};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Everything one experiment run owns.
pub struct Session<'rt> {
    pub rt: &'rt dyn Backend,
    pub mm: ModelManifest,
    pub cfg: ExperimentConfig,
    pub params: ParamStore,
    pub masks: MaskSet,
    /// Per-layer weight layouts + cached compressed forms (CSR/BSR, exact
    /// or quantised), rebuilt whenever the
    /// weights or masks change wholesale (prune / merge / load) so the
    /// retraining and serving hot loops never re-compress.
    pub sparse: SparseStore,
    /// Layout selection policy (`--layout`, config `layout`).
    pub layout: LayoutPolicy,
    pub lora: Option<(Mode, LoraState)>,
    pub corpus: Corpus,
    pub tokenizer: Tokenizer,
    pub train: Batcher,
    pub val: Batcher,
    pub test: Batcher,
    pub word_lut: Vec<i32>,
    pub rng: Rng,
    /// tokens/sec of the last retraining loop (Table 4)
    pub last_tps: f64,
    /// loss trace of the last (re)training loop
    pub last_losses: Vec<f32>,
}

impl<'rt> Session<'rt> {
    pub fn new(rt: &'rt dyn Backend, cfg: ExperimentConfig, seed: u64) -> Result<Session<'rt>> {
        let mm = rt.model(&cfg.model)?.clone();
        let mut rng = Rng::new(seed);
        let params = init::init_params(&mm, &mut rng);
        let masks = MaskSet::dense(&mm.prunable, |n| mm.param_shape(n).to_vec());

        // data: corpus sized to the model's vocab, tokenizer trained on the
        // rendered training split
        let corpus = Corpus::generate(CorpusConfig::for_vocab(mm.cfg.vocab, cfg.data_seed));
        let train_texts: Vec<String> = corpus.train.iter().map(|d| corpus.render(d)).collect();
        let val_texts: Vec<String> = corpus.val.iter().map(|d| corpus.render(d)).collect();
        let test_texts: Vec<String> = corpus.test.iter().map(|d| corpus.render(d)).collect();
        let tokenizer = Tokenizer::train(&train_texts, mm.cfg.vocab);
        let train = Batcher::new(&train_texts, &tokenizer, mm.cfg.seq_len);
        let val = Batcher::new(&val_texts, &tokenizer, mm.cfg.seq_len);
        let test = Batcher::new(&test_texts, &tokenizer, mm.cfg.seq_len);
        let word_lut = eval::word_token_lut(&corpus, &tokenizer);
        let layout = LayoutPolicy::parse(&cfg.layout).map_err(|e| anyhow::anyhow!(e))?;

        let mut s = Session {
            rt,
            mm,
            cfg,
            params,
            masks,
            sparse: SparseStore::default(),
            layout,
            lora: None,
            corpus,
            tokenizer,
            train,
            val,
            test,
            word_lut,
            rng,
            last_tps: 0.0,
            last_losses: Vec::new(),
        };
        s.refresh_sparse();
        Ok(s)
    }

    // ------------------------------------------------------------------
    // Sparse weight layout.
    // ------------------------------------------------------------------

    /// Re-resolve per-layer layouts and rebuild the compressed forms from the
    /// current `weight ⊙ mask` state.  Called after every wholesale
    /// weight/mask change (prune, merge, checkpoint load, full-FT
    /// retraining) — never per step, so hot loops reuse the cached forms.
    pub fn refresh_sparse(&mut self) {
        let store = SparseStore::build(
            self.layout,
            self.mm
                .prunable
                .iter()
                .map(|n| (n.clone(), self.params.get(n), self.masks.get(n))),
        );
        self.sparse = store;
    }

    /// Partial [`Session::refresh_sparse`]: re-resolve only `names`
    /// (layer-wise reconstruction mutates one block at a time — rescanning
    /// the whole model per block would be quadratic in depth).
    pub fn refresh_sparse_layers(&mut self, names: &[String]) {
        let mut store = std::mem::take(&mut self.sparse);
        store.update(
            self.layout,
            names.iter().map(|n| (n.clone(), self.params.get(n), self.masks.get(n))),
        );
        self.sparse = store;
    }

    /// The base feed for any executable over this session's model state:
    /// params + masks + the cached sparse-layout side channel.
    pub fn feed(&self) -> Feed<'_> {
        eval::base_feed(&self.params, &self.masks).sparse(&self.sparse)
    }

    // ------------------------------------------------------------------
    // Training loops.
    // ------------------------------------------------------------------

    /// Pretrain the dense model: full-FT steps with all-ones masks.
    pub fn pretrain(&mut self, steps: u64, peak_lr: f64) -> Result<()> {
        let schedule = Schedule::paper_default(peak_lr, steps);
        self.run_training(Mode::Full, steps, schedule)
    }

    /// PERP retraining after pruning, any mode.  Initialises adapters for
    /// LoRA modes (call [`Session::merge_adapters`] before evaluating).
    pub fn retrain(&mut self, mode: Mode, steps: u64, peak_lr: f64) -> Result<()> {
        if mode.is_lora() {
            let st = LoraState::init(&self.mm, mode, &mut self.rng.fork(77));
            self.lora = Some((mode, st));
        }
        let schedule = Schedule::paper_default(peak_lr, steps);
        self.run_training(mode, steps, schedule)
    }

    /// Retrain with a combo-subset executable (`train_<mode_key>`, from the
    /// --ablation artifact set).  No adapters involved.
    pub fn retrain_custom(&mut self, mode_key: &str, steps: u64, peak_lr: f64) -> Result<()> {
        let exec = format!("train_{mode_key}");
        let leaves = self
            .mm
            .trainable
            .get(mode_key)
            .with_context(|| format!("no trainable set {mode_key:?} in manifest"))?
            .clone();
        let schedule = Schedule::paper_default(peak_lr, steps);
        self.training_loop(&exec, leaves, false, steps, schedule)
    }

    fn run_training(&mut self, mode: Mode, steps: u64, schedule: Schedule) -> Result<()> {
        let exec = mode.executable().to_string();
        let leaf_names = self.leaf_names(mode);
        self.training_loop(&exec, leaf_names, mode.is_lora(), steps, schedule)
    }

    fn training_loop(
        &mut self,
        exec: &str,
        leaf_names: Vec<String>,
        _is_lora: bool,
        steps: u64,
        schedule: Schedule,
    ) -> Result<()> {
        let _sp = crate::span!("session", "train {exec}").arg("steps", steps);
        let mut opt = OptState::zeros(leaf_names.iter().map(|n| {
            let shape = self.leaf_shape(n);
            (n.as_str(), shape)
        }));
        let b = self.mm.cfg.train_batch;
        let s = self.mm.cfg.seq_len;
        let shape = [b, s];
        let mut meter = TpsMeter::new();
        let mut losses = Vec::with_capacity(steps as usize);
        let mut batch_rng = self.rng.fork(0xBA7C);
        // the cached compressed forms hold weight *values*, so they are only
        // valid while the prunable weights stay frozen — true for every PERP
        // subset/adapter mode, false for full FT (which rebuilds them once,
        // after the loop)
        let trains_weights = leaf_names.iter().any(|n| self.mm.prunable.contains(n));
        // quantised forms are approximate and therefore eval/decode-only:
        // a training forward must never read them, even when the weights
        // stay frozen, or the loss trace silently drifts off the masked path
        let forms_exact = !self.layout.may_quantise();

        for t in 1..=steps {
            let tokens = self.train.train_batch(b, &mut batch_rng);
            let lr = schedule.lr(t) as f32;

            let mut feed = eval::base_feed(&self.params, &self.masks)
                .ints("tokens", &shape, &tokens)
                .scalar("step", t as f32)
                .scalar("lr", lr);
            feed = if trains_weights || !forms_exact {
                // cached values would go stale as the weights move (or are
                // quantised and must not feed a training forward); layouts
                // alone keep an explicit --layout dense honoured
                feed.weight_layouts(&self.sparse)
            } else {
                feed.sparse(&self.sparse)
            };
            if let Some((_, lora)) = &self.lora {
                for (name, tsr) in &lora.tensors {
                    // borrow, don't clone: adapters can be the largest leaf
                    // tensors and this is the per-step hot path
                    let (lin, tag) = split_adapter_name(name);
                    feed = feed.owned_key(format!("{tag}::{lin}"), tsr);
                }
            }
            for n in &leaf_names {
                feed = feed
                    .tensor(&format!("om::{n}"), &opt.m[n])
                    .tensor(&format!("ov::{n}"), &opt.v[n]);
            }

            let mut out = self.rt.run(&self.mm.cfg.name, exec, &feed)?;
            losses.push(out.scalar("loss"));
            let new_leaves = out.drain_prefix("o::");
            let new_m = out.drain_prefix("om::");
            let new_v = out.drain_prefix("ov::");
            for (name, tsr) in new_leaves {
                self.write_leaf(&name, tsr);
            }
            for (name, tsr) in new_m {
                opt.m.insert(name, tsr);
            }
            for (name, tsr) in new_v {
                opt.v.insert(name, tsr);
            }
            meter.add_tokens((b * s) as u64);
        }
        self.last_tps = meter.tps();
        self.last_losses = losses;
        if trains_weights {
            self.refresh_sparse();
        }
        Ok(())
    }

    fn leaf_names(&self, mode: Mode) -> Vec<String> {
        let mut names = self
            .mm
            .trainable
            .get(mode.trainable_key())
            .cloned()
            .unwrap_or_default();
        if mode.is_lora() {
            names.extend(self.mm.adapters.iter().map(|(n, _)| n.clone()));
        }
        names
    }

    fn leaf_shape(&self, name: &str) -> &[usize] {
        if name.contains("::") {
            self.mm.adapter_shape(name)
        } else {
            self.mm.param_shape(name)
        }
    }

    fn write_leaf(&mut self, name: &str, t: Tensor) {
        if name.contains("::") {
            if let Some((_, lora)) = &mut self.lora {
                lora.set(name, t);
            } else {
                panic!("adapter output {name:?} without LoRA state");
            }
        } else {
            self.params.set(name, t);
        }
    }

    // ------------------------------------------------------------------
    // Calibration + pruning.
    // ------------------------------------------------------------------

    /// Accumulate per-prunable-linear Grams G = ΣXᵀX over the shared
    /// calibration set.
    pub fn calibrate(&mut self) -> Result<BTreeMap<String, Tensor>> {
        let _sp = crate::span!("session", "calibrate").arg("seqs", self.cfg.calib_seqs);
        let b = self.mm.cfg.eval_batch;
        let s = self.mm.cfg.seq_len;
        let shape = [b, s];
        let batches = self
            .train
            .calibration(self.cfg.calib_seqs, b, self.cfg.data_seed);
        let mut tap_grams: BTreeMap<String, Tensor> = BTreeMap::new();
        for tokens in &batches {
            let feed = self.feed().ints("tokens", &shape, tokens);
            let out = self.rt.run(&self.mm.cfg.name, "calib_stats", &feed)?;
            for (name, g) in out.values {
                let key = name.strip_prefix("gram::").unwrap_or(&name).to_string();
                tap_grams
                    .entry(key)
                    .and_modify(|acc| *acc = acc.add(&g))
                    .or_insert(g);
            }
        }
        // expand: q/k/v consume the same activations, hence the same Gram
        let mut grams = BTreeMap::new();
        for n in &self.mm.prunable {
            let tap = self.mm.taps.get(n).unwrap_or(n);
            let g = tap_grams
                .get(tap)
                .with_context(|| format!("no gram for tap {tap:?}"))?;
            grams.insert(n.clone(), g.clone());
        }
        Ok(grams)
    }

    /// Prune every prunable linear; SparseGPT also updates weights.
    /// `grams` required for Wanda/SparseGPT (from [`Session::calibrate`]).
    pub fn prune(
        &mut self,
        criterion: Criterion,
        pattern: Pattern,
        grams: Option<&BTreeMap<String, Tensor>>,
    ) -> Result<()> {
        let _sp = crate::span!("session", "prune {criterion:?}");
        match criterion {
            Criterion::Magnitude => {
                let weights: BTreeMap<String, &Tensor> = self
                    .mm
                    .prunable
                    .iter()
                    .map(|n| (n.clone(), self.params.get(n)))
                    .collect();
                self.masks = magnitude::uniform(&weights, pattern);
            }
            Criterion::MagnitudeGlobal => {
                let Pattern::Unstructured(f) = pattern else {
                    bail!("global magnitude needs unstructured sparsity");
                };
                let weights: BTreeMap<String, &Tensor> = self
                    .mm
                    .prunable
                    .iter()
                    .map(|n| (n.clone(), self.params.get(n)))
                    .collect();
                self.masks = magnitude::global(&weights, f);
            }
            Criterion::Wanda => {
                let grams = grams.context("wanda needs calibration grams")?;
                let mut masks = MaskSet::default();
                for n in &self.mm.prunable {
                    let m = wanda::mask(self.params.get(n), &grams[n], pattern);
                    masks.set(n, m);
                }
                self.masks = masks;
            }
            Criterion::SparseGpt => {
                let grams = grams.context("sparsegpt needs calibration grams")?;
                let mut masks = MaskSet::default();
                for n in &self.mm.prunable.clone() {
                    let res = sparsegpt::prune_layer(
                        self.params.get(n),
                        &grams[n],
                        pattern,
                        sparsegpt::DEFAULT_BLOCKSIZE,
                        sparsegpt::DEFAULT_PERCDAMP,
                    );
                    masks.set(n, res.mask);
                    self.params.set(n, res.weights);
                }
                self.masks = masks;
            }
        }
        // pruned weights are forced to exact zero (footnote 1 of the paper)
        self.params.apply_masks(&self.masks.masks);
        // compress once, here — retraining steps and serving reuse the forms
        self.refresh_sparse();
        Ok(())
    }

    // ------------------------------------------------------------------
    // Adapter merging.
    // ------------------------------------------------------------------

    /// Fold LoRA adapters back into the weights per the mode's merge rule;
    /// verifies sparsity preservation for the sparsity-preserving variants.
    pub fn merge_adapters(&mut self) -> Result<()> {
        let Some((mode, lora)) = self.lora.take() else {
            return Ok(()); // nothing to merge (subset modes)
        };
        let _sp = crate::span!("session", "merge {mode:?}");
        let scale = self.mm.cfg.lora_scale as f32;
        for n in &self.mm.prunable.clone() {
            let w = self.params.get(n);
            let mask = self.masks.get(n);
            let (a, b) = (lora.a(n), lora.b(n));
            let merged = match mode {
                Mode::Lora => merge::lora(w, a, b, scale),
                Mode::LoraPrune => merge::lora_prune(w, mask, a, b, scale),
                Mode::MaskLora | Mode::MaskLoraStd => merge::masklora(w, mask, a, b, scale),
                Mode::ScaleLora => merge::scalelora(w, mask, a, b),
                _ => unreachable!("merge on non-lora mode"),
            };
            if mode.mergeable_sparsity_preserving() == Some(true) {
                assert!(
                    merge::preserves_sparsity(&merged, mask),
                    "{mode:?} merge resurrected pruned weights in {n}"
                );
            }
            self.params.set(n, merged);
        }
        // merged weights replace the frozen ones the compressed forms were built
        // from — recompress before eval/serve touch them
        self.refresh_sparse();
        Ok(())
    }

    // ------------------------------------------------------------------
    // Evaluation.
    // ------------------------------------------------------------------

    pub fn eval_ppl_val(&self) -> Result<PplResult> {
        self.eval_ppl_with(&self.val)
    }

    pub fn eval_ppl_test(&self) -> Result<PplResult> {
        self.eval_ppl_with(&self.test)
    }

    fn eval_ppl_with(&self, batcher: &Batcher) -> Result<PplResult> {
        let _sp = crate::span!("session", "eval.ppl").arg("batches", self.cfg.eval_batches);
        match &self.lora {
            None => eval::perplexity(
                self.rt, &self.mm, &self.params, &self.masks, Some(&self.sparse), batcher,
                self.cfg.eval_batches,
            ),
            // standard LoRA is the one variant evaluated UNMERGED (merging
            // would destroy sparsity — its extra inference cost is the
            // paper's argument against it)
            Some((Mode::Lora, lora)) => eval::perplexity_lora(
                self.rt, &self.mm, &self.params, &self.masks, Some(&self.sparse), lora, batcher,
                self.cfg.eval_batches,
            ),
            Some((mode, _)) => {
                bail!("merge adapters before eval (mode {mode:?} still active)")
            }
        }
    }

    pub fn eval_tasks(&self) -> Result<Vec<TaskResult>> {
        let _sp = crate::span!("session", "eval.tasks");
        let lora = match &self.lora {
            None => None,
            Some((Mode::Lora, lora)) => Some(lora),
            Some((mode, _)) => bail!("merge adapters before eval (mode {mode:?})"),
        };
        let suite = tasks::build_suite(&self.corpus, self.cfg.items_per_task, self.cfg.data_seed ^ 0x7A5C);
        eval::zero_shot(
            self.rt,
            &self.mm,
            &self.params,
            &self.masks,
            Some(&self.sparse),
            lora,
            &suite,
            &self.word_lut,
        )
    }

    // ------------------------------------------------------------------
    // Checkpoints.
    // ------------------------------------------------------------------

    /// A session whose weights come from a saved checkpoint — the entry
    /// point for `repro eval --from` and the serving layer, which evaluate
    /// and serve pruned/retrained/merged artifacts in the same `.ptns`
    /// format the pipeline writes.  Masks stay dense: pruned checkpoints
    /// carry their zeros in the weights themselves.
    pub fn from_checkpoint(
        rt: &'rt dyn Backend,
        cfg: ExperimentConfig,
        seed: u64,
        path: &Path,
    ) -> Result<Session<'rt>> {
        let mut s = Session::new(rt, cfg, seed)?;
        s.load(path)?;
        Ok(s)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.params.save(path)
    }

    pub fn load(&mut self, path: &Path) -> Result<()> {
        self.params = ParamStore::load(&self.mm, path)?;
        // pruned checkpoints carry zeros in the weights under all-ones
        // masks; the rebuild measures W⊙M sparsity, so they still compress
        self.refresh_sparse();
        Ok(())
    }

    /// Restore dense state: all-ones masks (params unchanged).
    pub fn reset_masks(&mut self) {
        let mm = &self.mm;
        self.masks = MaskSet::dense(&mm.prunable, |n| mm.param_shape(n).to_vec());
        self.refresh_sparse();
    }
}

// Canonical decoder lives next to the adapter inventory; re-exported here
// for the coordinator/eval call sites that predate the backend split.
pub use crate::runtime::manifest::split_adapter_name;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adapter_name_split() {
        assert_eq!(split_adapter_name("x_w::A"), ("x_w", "a"));
        assert_eq!(split_adapter_name("x_w::B"), ("x_w", "b"));
    }

    #[test]
    #[should_panic]
    fn bad_adapter_name_panics() {
        split_adapter_name("plain");
    }
}
