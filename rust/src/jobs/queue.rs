//! [`JobManager`]: the daemon's in-memory queue over the durable
//! [`JobStore`](super::store::JobStore).
//!
//! The store is the source of truth; the manager is a rebuildable view:
//! [`JobManager::open`] rescans `job.json` records on boot, requeues
//! everything non-terminal (a job found `running` was interrupted by a
//! crash or kill — its running nodes reset to `pending` and it resumes
//! through the stage cache) unless a durable cancel marker says the job
//! was cancelled before the kill (then it goes terminal instead), and
//! from then on mediates submit/dequeue/cancel between the HTTP handlers
//! and the worker pool.  Job ids come from a counter inside the mutex
//! (seeded from the store once at open), so concurrent submits are
//! collision-free by construction.
//!
//! Metrics (all in the global [`Registry`]): gauges `jobs.queued` /
//! `jobs.running` track live depths; counters `jobs.submitted`,
//! `jobs.done`, `jobs.failed`, `jobs.cancelled`, `jobs.resumed`
//! accumulate transitions; histogram `jobs.queue_wait_s` observes
//! dequeue latency.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Context, Result};

use crate::obs::counters::Registry;

use super::store::{now_unix, JobRecord, JobSpec, JobStatus, JobStore};

struct Inner {
    queue: VecDeque<String>,
    /// per-running-job cancel flags (shared with the executing runner)
    running: BTreeMap<String, Arc<AtomicBool>>,
    /// running jobs whose flag was set by an explicit cancel (vs shutdown)
    cancelled: BTreeSet<String>,
    /// next job id number — seeded from the store at open and only ever
    /// read/bumped under this mutex, so concurrent submits can't collide
    next_id: u64,
    shutting_down: bool,
}

/// Thread-safe job queue + store facade shared by the HTTP handlers and
/// the worker pool.
pub struct JobManager {
    store: JobStore,
    inner: Mutex<Inner>,
    cv: Condvar,
}

impl JobManager {
    /// Open (or create) the store at `root` and rebuild the queue from it.
    pub fn open(root: &std::path::Path) -> Result<JobManager> {
        let store = JobStore::open(root)?;
        let next_id = store.next_id_num()?;
        let mut queue = VecDeque::new();
        for mut rec in store.list()? {
            match rec.status {
                JobStatus::Running | JobStatus::Queued => {
                    // a cancel acknowledged before the kill wins over resume:
                    // the marker survives on disk even when the final
                    // `job.json` save never happened
                    if store.cancel_requested(&rec.id) {
                        rec.reset_running_nodes();
                        rec.status = JobStatus::Cancelled;
                        rec.finished_unix = Some(now_unix());
                        rec.warnings.push(
                            "cancelled on daemon boot (cancel acknowledged before shutdown)"
                                .to_string(),
                        );
                        store.save(&rec)?;
                        store.clear_cancel(&rec.id);
                        crate::count!("jobs.cancelled");
                    } else if rec.status == JobStatus::Running {
                        // interrupted by a crash/kill mid-run: resume from
                        // the stage cache on this boot
                        rec.reset_running_nodes();
                        rec.status = JobStatus::Queued;
                        rec.queued_unix = now_unix();
                        rec.warnings.push(format!(
                            "requeued on daemon boot after interrupted attempt {}",
                            rec.attempts
                        ));
                        store.save(&rec)?;
                        crate::count!("jobs.resumed");
                        queue.push_back(rec.id);
                    } else {
                        queue.push_back(rec.id);
                    }
                }
                _ => {}
            }
        }
        let mgr = JobManager {
            store,
            inner: Mutex::new(Inner {
                queue,
                running: BTreeMap::new(),
                cancelled: BTreeSet::new(),
                next_id,
                shutting_down: false,
            }),
            cv: Condvar::new(),
        };
        mgr.sync_gauges(&mgr.lock());
        Ok(mgr)
    }

    pub fn store(&self) -> &JobStore {
        &self.store
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn sync_gauges(&self, inner: &Inner) {
        let reg = Registry::global();
        reg.set_gauge("jobs.queued", inner.queue.len() as u64);
        reg.set_gauge("jobs.running", inner.running.len() as u64);
    }

    /// Persist a new queued job and wake a worker.  Fails (without
    /// persisting anything or consuming an id) on invalid graphs/configs
    /// and during shutdown.  The id is allocated from the serialized
    /// counter while the lock is held — concurrent submits can never hand
    /// two clients the same id or overwrite each other's `job.json`.
    pub fn submit(&self, spec: JobSpec) -> Result<String> {
        let mut inner = self.lock();
        if inner.shutting_down {
            bail!("daemon is shutting down; not accepting jobs");
        }
        let id = JobStore::format_id(inner.next_id);
        let rec = JobRecord::new(&id, spec, now_unix())?;
        self.store.save(&rec)?;
        inner.next_id += 1;
        inner.queue.push_back(id.clone());
        crate::count!("jobs.submitted");
        self.sync_gauges(&inner);
        drop(inner);
        self.cv.notify_one();
        Ok(id)
    }

    /// Block until a job is ready (or shutdown begins — then `None`).
    /// Returns the job id plus its fresh cancel flag.
    pub fn dequeue(&self) -> Option<(String, Arc<AtomicBool>)> {
        let mut inner = self.lock();
        loop {
            if inner.shutting_down {
                return None;
            }
            if let Some(id) = inner.queue.pop_front() {
                let flag = Arc::new(AtomicBool::new(false));
                inner.running.insert(id.clone(), flag.clone());
                self.sync_gauges(&inner);
                return Some((id, flag));
            }
            inner = self.cv.wait(inner).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// A runner finished (or abandoned) a job: drop its flag bookkeeping.
    pub fn finish(&self, id: &str) {
        let mut inner = self.lock();
        inner.running.remove(id);
        inner.cancelled.remove(id);
        self.sync_gauges(&inner);
    }

    /// Was this running job's flag set by an explicit cancel request (vs a
    /// daemon shutdown)?  Decides `cancelled` vs `queued` on interrupt.
    pub fn was_cancelled(&self, id: &str) -> bool {
        self.lock().cancelled.contains(id)
    }

    pub fn is_shutting_down(&self) -> bool {
        self.lock().shutting_down
    }

    /// Current queue depth (jobs waiting, not running).
    pub fn queued_len(&self) -> usize {
        self.lock().queue.len()
    }

    /// Cancel a job.  Queued jobs become `cancelled` immediately; running
    /// jobs get a durable cancel marker (so the acknowledgement survives a
    /// daemon kill — boot rescan cancels instead of resuming) plus their
    /// in-memory flag, and finish their in-flight nodes first.  Returns a
    /// short status word for the HTTP response.
    pub fn cancel(&self, id: &str) -> Result<&'static str> {
        let mut inner = self.lock();
        if let Some(flag) = inner.running.get(id) {
            // persist before acknowledging: if this fails the client gets
            // an error and no half-cancelled state was recorded anywhere
            self.store.request_cancel(id)?;
            flag.store(true, Ordering::Relaxed);
            inner.cancelled.insert(id.to_string());
            return Ok("cancelling");
        }
        if let Some(pos) = inner.queue.iter().position(|q| q == id) {
            inner.queue.remove(pos);
            let mut rec = self.store.load(id)?;
            rec.status = JobStatus::Cancelled;
            rec.finished_unix = Some(now_unix());
            self.store.save(&rec)?;
            crate::count!("jobs.cancelled");
            self.sync_gauges(&inner);
            return Ok("cancelled");
        }
        let rec = self.store.load(id).with_context(|| format!("no such job {id:?}"))?;
        bail!("job {id} is {} — nothing to cancel", rec.status.as_str());
    }

    /// Begin graceful shutdown: stop dequeuing, set every running job's
    /// flag (WITHOUT marking them cancelled — they requeue for resume),
    /// wake all blocked workers so they observe the state and exit.
    pub fn begin_shutdown(&self) {
        let mut inner = self.lock();
        inner.shutting_down = true;
        for flag in inner.running.values() {
            flag.store(true, Ordering::Relaxed);
        }
        drop(inner);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::jobs::store::NodeStatus;
    use crate::pipeline::parse::parse_graph;

    fn spec(name: &str) -> JobSpec {
        JobSpec {
            name: name.to_string(),
            graph: parse_graph(name, "prune(magnitude,0.5)|eval(ppl)").unwrap(),
            cfg: ExperimentConfig::quick("gpt-nano"),
            seed: 0,
            jobs: 1,
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("perp_jobq_{tag}_{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    }

    #[test]
    fn submit_dequeue_cancel_lifecycle() {
        let root = tmp("lifecycle");
        let mgr = JobManager::open(&root).unwrap();
        let a = mgr.submit(spec("a")).unwrap();
        let b = mgr.submit(spec("b")).unwrap();
        assert_eq!((a.as_str(), b.as_str()), ("j0001", "j0002"));
        // cancel while queued → terminal immediately
        assert_eq!(mgr.cancel(&b).unwrap(), "cancelled");
        assert_eq!(mgr.store().load(&b).unwrap().status, JobStatus::Cancelled);
        // dequeue hands out the remaining job with an unset flag
        let (id, flag) = mgr.dequeue().unwrap();
        assert_eq!(id, a);
        assert!(!flag.load(Ordering::Relaxed));
        // cancel while running → flag set, remembered as explicit
        assert_eq!(mgr.cancel(&a).unwrap(), "cancelling");
        assert!(flag.load(Ordering::Relaxed));
        assert!(mgr.was_cancelled(&a));
        mgr.finish(&a);
        // terminal cancel is an error
        assert!(mgr.cancel(&b).is_err());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn boot_rescan_requeues_interrupted_jobs() {
        let root = tmp("rescan");
        {
            let mgr = JobManager::open(&root).unwrap();
            let id = mgr.submit(spec("a")).unwrap();
            // simulate a crash mid-run: persist as running, drop the manager
            let mut rec = mgr.store().load(&id).unwrap();
            rec.status = JobStatus::Running;
            rec.attempts = 1;
            let node = rec.nodes.keys().next().unwrap().clone();
            rec.nodes.get_mut(&node).unwrap().status = NodeStatus::Running;
            mgr.store().save(&rec).unwrap();
        }
        let mgr = JobManager::open(&root).unwrap();
        let rec = mgr.store().load("j0001").unwrap();
        assert_eq!(rec.status, JobStatus::Queued);
        assert!(rec.warnings.iter().any(|w| w.contains("requeued on daemon boot")));
        assert!(rec.nodes.values().all(|n| n.status == NodeStatus::Pending));
        // and it is actually dequeueable
        let (id, _) = mgr.dequeue().unwrap();
        assert_eq!(id, "j0001");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn concurrent_submits_get_unique_ids() {
        let root = tmp("concurrent");
        let mgr = Arc::new(JobManager::open(&root).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let mgr = Arc::clone(&mgr);
            handles.push(std::thread::spawn(move || {
                (0..4).map(|i| mgr.submit(spec(&format!("t{t}_{i}"))).unwrap()).collect::<Vec<_>>()
            }));
        }
        let mut ids: Vec<String> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 32, "every submit must get a distinct id");
        // and every id's record survived on disk (nothing overwritten)
        assert_eq!(mgr.store().ids().unwrap().len(), 32);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn running_cancel_is_durable_across_boot() {
        let root = tmp("durable_cancel");
        {
            let mgr = JobManager::open(&root).unwrap();
            let id = mgr.submit(spec("a")).unwrap();
            let (got, _flag) = mgr.dequeue().unwrap();
            assert_eq!(got, id);
            // simulate the worker having persisted `running`, then a cancel
            // acknowledged, then SIGKILL before the worker's final save
            let mut rec = mgr.store().load(&id).unwrap();
            rec.status = JobStatus::Running;
            mgr.store().save(&rec).unwrap();
            assert_eq!(mgr.cancel(&id).unwrap(), "cancelling");
            assert!(mgr.store().cancel_requested(&id), "ack must be durable");
        }
        let mgr = JobManager::open(&root).unwrap();
        let rec = mgr.store().load("j0001").unwrap();
        assert_eq!(rec.status, JobStatus::Cancelled, "boot honors the acknowledged cancel");
        assert!(!mgr.store().cancel_requested("j0001"), "marker consumed");
        assert_eq!(mgr.queued_len(), 0, "cancelled job must not requeue");
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn shutdown_stops_dequeue_and_flags_running() {
        let root = tmp("shutdown");
        let mgr = JobManager::open(&root).unwrap();
        let a = mgr.submit(spec("a")).unwrap();
        let (_, flag) = mgr.dequeue().unwrap();
        mgr.begin_shutdown();
        assert!(flag.load(Ordering::Relaxed), "running flag set on shutdown");
        assert!(!mgr.was_cancelled(&a), "shutdown is not an explicit cancel");
        assert!(mgr.dequeue().is_none(), "no dequeue during shutdown");
        assert!(mgr.submit(spec("b")).is_err(), "no submit during shutdown");
        std::fs::remove_dir_all(&root).ok();
    }
}
