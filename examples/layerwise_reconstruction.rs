//! Layer-wise reconstruction demo (PERP §3.3): enhance magnitude, Wanda and
//! SparseGPT with memory-efficient MaskLoRA reconstruction.
//!
//! ```bash
//! cargo run --release --offline --example layerwise_reconstruction -- \
//!     [--model gpt-nano] [--sparsity 0.6]
//! ```

use anyhow::Result;

use perp::config::ExperimentConfig;
use perp::coordinator::reconstruct::{reconstruct, ReconMode};
use perp::coordinator::sweep::ExpContext;
use perp::metrics::training_memory;
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{open_default_backend, Backend};
use perp::util::cli::Args;

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv).map_err(|e| anyhow::anyhow!(e))?;
    let model = args.str("model", "gpt-nano");
    let pattern = Pattern::parse(&args.str("sparsity", "0.6")).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let rt = open_default_backend()?;
    let mut cfg = ExperimentConfig::quick(&model);
    cfg.pretrain_steps = 3000;
    cfg.recon_steps = 40;
    let ctx = ExpContext::new(rt.as_ref(), cfg.clone(), "results/cache".into());

    let dense = ctx.dense_session(0)?;
    let dense_ppl = dense.eval_ppl_test()?.ppl;
    println!("dense ppl: {dense_ppl:.2}\n");
    println!(
        "{:<18} {:>12} {:>14} {:>10}",
        "pruner", "ppl (no rec)", "ppl (masklora)", "Δ"
    );

    for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt] {
        let (base, dense_w) = ctx.pruned_session(0, crit, pattern)?;
        let before = base.eval_ppl_test()?.ppl;
        let mut s = ctx.clone_session(&base)?;
        let target = s.masks.clone();
        reconstruct(&mut s, &target, &dense_w, ReconMode::MaskLora, cfg.recon_steps, cfg.recon_lr)?;
        let after = s.eval_ppl_test()?.ppl;
        println!(
            "{:<18} {:>12.2} {:>14.2} {:>9.1}%",
            crit.name(),
            before,
            after,
            100.0 * (before - after) / before
        );
    }

    // the memory argument: global retraining vs one-block reconstruction
    let mm = rt.model(&model)?.clone();
    let tokens = (mm.cfg.train_batch * mm.cfg.seq_len) as u64;
    let full = training_memory(
        mm.total_params() as u64,
        mm.total_params() as u64,
        tokens,
        mm.cfg.d_model as u64,
        mm.cfg.n_layers as u64,
        4,
        false,
    );
    let recon = training_memory(
        mm.total_params() as u64,
        (2 * mm.cfg.lora_rank * (mm.cfg.d_model + mm.cfg.d_ff)) as u64,
        tokens,
        mm.cfg.d_model as u64,
        mm.cfg.n_layers as u64,
        4,
        true,
    );
    println!(
        "\nmemory (this scale): full retraining {:.2} MiB vs layer-wise reconstruction {:.2} MiB ({}x less)",
        full.total() as f64 / (1 << 20) as f64,
        recon.total() as f64 / (1 << 20) as f64,
        full.total() / recon.total().max(1)
    );
    Ok(())
}
