//! `perp::spec` — the speculative draft-verify decode engine.
//!
//! PERP manufactures its own draft models: a pruned+retrained variant
//! recovers dense-level quality from a fraction of the parameters, so it
//! proposes tokens cheaply while the dense target stays the source of
//! truth.  Each round the [`SpecEngine`] runs up to `k` greedy draft steps
//! against a dedicated draft KV plane, verifies every proposal in one
//! batched multi-token `verify_step` pass over the target cache, accepts
//! the longest matching prefix plus the target's own next token, and rolls
//! both planes back to the divergence point with [`KvCache::truncate_to`].
//!
//! **Exactness.**  Both sides decode greedily (first-maximum [`argmax`]),
//! and `verify_step`'s logits rows are bitwise what sequential
//! `decode_step` calls would produce (see `runtime/native/verify.rs`), so
//! a proposal is accepted *iff* plain target-only decoding would have
//! emitted that exact token.  By induction the committed stream is
//! bitwise-identical to never having speculated — pinned end-to-end by
//! `tests/decode_parity.rs` — and speculation is purely a latency play:
//! `m` accepted tokens cost one verify pass instead of `m` decode steps.
//! The guarantee is greedy-only: at `temperature > 0` the batcher bypasses
//! this engine entirely.
//!
//! **Bookkeeping.**  The draft cache runs one round behind the target: a
//! round that accepts `m` of `keff` proposals leaves the draft holding
//! `pending` tokens (committed to the target, not yet fed to the draft)
//! satisfying `draft_pos + pending.len() == target_pos`.  The engine owns
//! all cache writes and truncations; the batcher only consumes the
//! committed tokens through its ordinary `advance` path, so EOS /
//! max-tokens / cache-full semantics are shared with plain decoding.

use anyhow::Result;

use crate::runtime::{ModelCfg, Outputs};

use super::batcher::argmax;
use super::kv::KvCache;

/// Per-slot draft bookkeeping: where the draft cache is, and which
/// already-committed target tokens it still has to consume.
#[derive(Debug, Clone, Default)]
struct SpecState {
    /// Valid draft cache rows (== next draft write position).
    draft_pos: usize,
    /// Committed target tokens not yet fed to the draft.  Together with
    /// the stream's `last` token this is the next round's feed queue.
    pending: Vec<i32>,
}

/// One active stream's view for a spec round.
#[derive(Debug, Clone, Copy)]
pub struct RoundInput {
    pub slot: usize,
    /// Valid target cache rows (the batcher's `Stream::pos`).
    pub pos: usize,
    /// Last committed token — the verify window's first input.
    pub last: i32,
}

/// What one stream got out of a round.
#[derive(Debug, Clone)]
pub struct RoundResult {
    pub slot: usize,
    /// `accepted` draft tokens plus the target's next token — in plain
    /// decoding order.  The caller feeds these through `advance` one at a
    /// time; position `pos + i + 1` is valid after consuming token `i`.
    pub committed: Vec<i32>,
    /// Draft tokens proposed this round (`keff <= k`, window-clamped).
    pub proposed: usize,
    /// Leading proposals the target agreed with (`<= proposed`).
    pub accepted: usize,
}

/// Counters one round accumulates — the batcher folds these into
/// `EngineMetrics` (and the obs registry is fed directly in here).
#[derive(Debug, Clone, Copy, Default)]
pub struct RoundStats {
    /// Batched draft `decode_step` calls this round.
    pub draft_steps: u64,
    pub proposed: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Streams that needed a target-plane rollback (some proposal refused).
    pub rollbacks: u64,
}

/// One stream's in-flight drafting state within a round.
struct Drafting {
    slot: usize,
    /// Target position at round entry (verify window base).
    tpos: usize,
    /// Draft cache position at round entry (first write position).
    dpos: usize,
    /// Pending committed tokens then `last`; past it, own proposals.
    queue: Vec<i32>,
    keff: usize,
    fed: usize,
    proposals: Vec<i32>,
}

impl Drafting {
    /// `(token, active)` for the next micro-step.  A stream stays active
    /// until it has proposed `keff` tokens; the final proposal is sampled
    /// but never fed (its K/V row would be rolled back regardless).
    fn next_feed(&self) -> (i32, bool) {
        if self.proposals.len() >= self.keff {
            return (0, false);
        }
        let tok = if self.fed < self.queue.len() {
            self.queue[self.fed]
        } else {
            self.proposals[self.fed - self.queue.len()]
        };
        (tok, true)
    }
}

pub struct SpecEngine {
    /// Requested draft length; clamped to `spec_width - 1` (one verify row
    /// carries the committed input token).
    pub k: usize,
    sw: usize,
    seq: usize,
    draft: KvCache,
    states: Vec<Option<SpecState>>,
}

impl SpecEngine {
    /// `cfg` is the shared model config (draft and target are the same
    /// architecture — the draft differs only in weights/sparsity).
    pub fn new(cfg: &ModelCfg, k: usize) -> SpecEngine {
        let sw = cfg.spec_width;
        SpecEngine {
            k: k.clamp(1, sw.saturating_sub(1).max(1)),
            sw,
            seq: cfg.seq_len,
            draft: KvCache::new(cfg),
            states: (0..cfg.serve_slots).map(|_| None).collect(),
        }
    }

    /// The draft KV planes — the batcher adopts the draft model's prefill
    /// output into these (same slot indices as the target cache).
    pub fn draft_cache(&mut self) -> &mut KvCache {
        &mut self.draft
    }

    /// Register a freshly admitted stream after its draft prefill:
    /// `prompt_len` rows of the draft plane are valid, nothing pending.
    pub fn admit(&mut self, slot: usize, prompt_len: usize) {
        self.states[slot] = Some(SpecState { draft_pos: prompt_len, pending: Vec::new() });
    }

    /// Stream `slot` is tracked for speculative rounds.
    pub fn tracks(&self, slot: usize) -> bool {
        self.states[slot].is_some()
    }

    /// Drop a finished stream's spec state.
    pub fn release(&mut self, slot: usize) {
        self.states[slot] = None;
    }

    /// Run one draft-propose / target-verify round over `streams`.
    ///
    /// `draft_step(draft_cache, tokens, pos)` runs the draft model's
    /// `decode_step`; `verify(target_cache, tokens, pos, klen)` runs the
    /// target's `verify_step` — closures, so the engine stays agnostic of
    /// sessions and backends (the parity test drives it directly).  All
    /// cache writes and rollbacks happen in here; on return the target
    /// cache holds exactly `pos + committed.len()` valid rows per stream.
    pub fn round<FD, FV>(
        &mut self,
        target: &mut KvCache,
        streams: &[RoundInput],
        mut draft_step: FD,
        mut verify: FV,
    ) -> Result<(Vec<RoundResult>, RoundStats)>
    where
        FD: FnMut(&KvCache, &[i32], &[i32]) -> Result<Outputs>,
        FV: FnMut(&KvCache, &[i32], &[i32], &[i32]) -> Result<Outputs>,
    {
        let slots = self.states.len();
        let (sw, seq) = (self.sw, self.seq);
        let mut stats = RoundStats::default();

        // ---- 1. draft: flush pending + propose keff tokens per stream --
        // Micro-steps stay batched across streams — one draft decode_step
        // per step, streams going idle (pos = -1) as their budget is met.
        let mut drafting: Vec<Drafting> = Vec::with_capacity(streams.len());
        for s in streams {
            let st = self.states[s.slot]
                .as_ref()
                .unwrap_or_else(|| panic!("spec round over untracked slot {}", s.slot));
            debug_assert_eq!(
                st.draft_pos + st.pending.len(),
                s.pos,
                "draft lag invariant broken on slot {}",
                s.slot
            );
            // the verify window writes rows pos..=pos+keff, all < seq
            let keff = self.k.min(seq.saturating_sub(s.pos + 1));
            let mut queue = st.pending.clone();
            queue.push(s.last);
            drafting.push(Drafting {
                slot: s.slot,
                tpos: s.pos,
                dpos: st.draft_pos,
                queue,
                keff,
                fed: 0,
                proposals: Vec::new(),
            });
        }
        let mut step_tokens = vec![0i32; slots];
        let mut step_pos = vec![-1i32; slots];
        loop {
            let mut any = false;
            step_pos.iter_mut().for_each(|p| *p = -1);
            for d in &drafting {
                let (tok, active) = d.next_feed();
                if active {
                    any = true;
                    step_tokens[d.slot] = tok;
                    step_pos[d.slot] = (d.dpos + d.fed) as i32;
                }
            }
            if !any {
                break;
            }
            let out = {
                let _sp = crate::span!("spec", "draft_step");
                draft_step(&self.draft, &step_tokens, &step_pos)?
            };
            stats.draft_steps += 1;
            crate::count!("spec.draft_steps");
            for layer in 0..self.draft.n_layers() {
                let kn = out.get(&format!("knew::h{layer}"));
                let vn = out.get(&format!("vnew::h{layer}"));
                for d in &drafting {
                    if step_pos[d.slot] >= 0 {
                        self.draft.write_new(d.slot, d.dpos + d.fed, layer, kn, vn);
                    }
                }
            }
            let logits = out.get("logits");
            let vocab = logits.cols();
            for d in drafting.iter_mut() {
                if step_pos[d.slot] < 0 {
                    continue;
                }
                d.fed += 1;
                // logits past the queue's last token are proposals
                if d.fed >= d.queue.len() {
                    let row = &logits.data()[d.slot * vocab..(d.slot + 1) * vocab];
                    d.proposals.push(argmax(row));
                }
            }
        }

        // ---- 2. verify every window in one multi-token target pass -----
        let mut vtokens = vec![0i32; slots * sw];
        let mut vpos = vec![-1i32; slots];
        let mut vklen = vec![0i32; slots];
        for d in &drafting {
            vtokens[d.slot * sw] = *d.queue.last().expect("queue holds at least `last`");
            for (i, &p) in d.proposals.iter().enumerate() {
                vtokens[d.slot * sw + 1 + i] = p;
            }
            vpos[d.slot] = d.tpos as i32;
            vklen[d.slot] = (d.proposals.len() + 1) as i32;
        }
        let out = {
            let _sp = crate::span!("spec", "verify_step").arg("streams", drafting.len());
            verify(target, &vtokens, &vpos, &vklen)?
        };
        crate::count!("spec.verify_steps");

        // ---- 3. accept the longest matching prefix, roll back the rest -
        let logits = out.get("logits");
        let vocab = logits.data().len() / (slots * sw);
        let mut results = Vec::with_capacity(drafting.len());
        for d in &drafting {
            let (p, keff) = (d.tpos, d.keff);
            let klen = d.proposals.len() + 1;
            let row = |j: usize| {
                let base = (d.slot * sw + j) * vocab;
                &logits.data()[base..base + vocab]
            };
            // proposal i (0-based) survives iff it matches the target's
            // argmax at the same position and every earlier proposal did
            let mut m = 0usize;
            while m < d.proposals.len() && d.proposals[m] == argmax(row(m)) {
                m += 1;
            }
            let mut committed: Vec<i32> = d.proposals[..m].to_vec();
            committed.push(argmax(row(m))); // the target's own next token

            // target plane: commit all klen fresh rows, then roll back to
            // the divergence point — bitwise "never drafted" (kv.rs tests)
            for layer in 0..target.n_layers() {
                let kn = out.get(&format!("knew::h{layer}"));
                let vn = out.get(&format!("vnew::h{layer}"));
                for j in 0..klen {
                    target.write_spec(d.slot, p + j, layer, j, sw, kn, vn);
                }
            }
            target.truncate_to(d.slot, p + m + 1);

            // draft plane: rows for rejected proposals are invalid; on a
            // full accept the final (never-fed) proposal becomes pending.
            // keff == 0 means the cache fills this round and the caller
            // releases the stream — leave its draft state alone.
            if keff > 0 {
                let st = self.states[d.slot].as_mut().expect("tracked");
                st.draft_pos = p + keff.min(m + 1);
                st.pending.clear();
                if m == keff {
                    st.pending.push(d.proposals[keff - 1]);
                }
                self.draft.truncate_to(d.slot, st.draft_pos);
            }

            stats.proposed += keff as u64;
            stats.accepted += m as u64;
            stats.rejected += (keff - m) as u64;
            if m < keff {
                stats.rollbacks += 1;
                crate::count!("spec.rollbacks");
            }
            crate::count!("spec.accepted", m as u64);
            crate::count!("spec.rejected", (keff - m) as u64);
            crate::obs::counters::Registry::global().observe("spec.accept_len", m as f64);
            results.push(RoundResult { slot: d.slot, committed, proposed: keff, accepted: m });
        }
        Ok((results, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelCfg;
    use crate::tensor::Tensor;

    fn cfg() -> ModelCfg {
        ModelCfg::builtin("gpt-nano").unwrap()
    }

    /// Fake draft: argmax of the logits row for slot 0 walks 10, 11, 12 …
    /// across successive calls; K/V rows are zeros.
    fn fake_draft(cfg: &ModelCfg, call: &mut usize) -> Outputs {
        let (slots, vocab) = (cfg.serve_slots, cfg.vocab);
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let mut lg = vec![0.0f32; slots * vocab];
        lg[10 + *call] = 1.0; // slot 0 argmax = 10 + call
        *call += 1;
        let mut values = vec![("logits".to_string(), Tensor::new(&[slots, vocab], lg))];
        for i in 0..cfg.n_layers {
            values.push((format!("knew::h{i}"), Tensor::zeros(&[slots, nh, dh])));
            values.push((format!("vnew::h{i}"), Tensor::zeros(&[slots, nh, dh])));
        }
        Outputs { values }
    }

    /// Fake verify: rows 0 and 1 agree with proposals 10 and 11, row 2
    /// insists on 99 (rejecting proposal 12), later rows pick 0.
    fn fake_verify(cfg: &ModelCfg) -> Outputs {
        let (slots, vocab, sw) = (cfg.serve_slots, cfg.vocab, cfg.spec_width);
        let (nh, dh) = (cfg.n_heads, cfg.d_head());
        let mut lg = vec![0.0f32; slots * sw * vocab];
        lg[10] = 1.0; // row 0 -> 10
        lg[vocab + 11] = 1.0; // row 1 -> 11
        lg[2 * vocab + 99] = 1.0; // row 2 -> 99 (diverges from 12)
        let mut values = vec![("logits".to_string(), Tensor::new(&[slots, sw, vocab], lg))];
        for i in 0..cfg.n_layers {
            values.push((format!("knew::h{i}"), Tensor::zeros(&[slots, sw, nh, dh])));
            values.push((format!("vnew::h{i}"), Tensor::zeros(&[slots, sw, nh, dh])));
        }
        Outputs { values }
    }

    #[test]
    fn round_accepts_prefix_and_keeps_the_lag_invariant() {
        let cfg = cfg();
        let mut eng = SpecEngine::new(&cfg, 3);
        let mut target = KvCache::new(&cfg);
        eng.admit(0, 4);
        assert!(eng.tracks(0));

        let mut call = 0usize;
        let mut fed: Vec<(i32, i32)> = Vec::new(); // (token, pos) fed to the draft
        let (results, stats) = eng
            .round(
                &mut target,
                &[RoundInput { slot: 0, pos: 4, last: 7 }],
                |_, toks, pos| {
                    fed.push((toks[0], pos[0]));
                    Ok(fake_draft(&cfg, &mut call))
                },
                |_, toks, pos, klen| {
                    assert_eq!(&toks[..4], &[7, 10, 11, 12]);
                    assert_eq!(pos[0], 4);
                    assert_eq!(klen[0], 4);
                    Ok(fake_verify(&cfg))
                },
            )
            .unwrap();

        // drafted `last` then its own proposals, in position order
        assert_eq!(fed, vec![(7, 4), (10, 5), (11, 6)]);
        assert_eq!(results.len(), 1);
        let r = &results[0];
        assert_eq!(r.committed, vec![10, 11, 99]);
        assert_eq!((r.proposed, r.accepted), (3, 2));
        assert_eq!(stats.draft_steps, 3);
        assert_eq!((stats.proposed, stats.accepted, stats.rejected), (3, 2, 1));
        assert_eq!(stats.rollbacks, 1);

        // next round entry at pos 7 (= 4 + committed.len()) must satisfy
        // the draft-lag invariant — the debug_assert inside round checks it
        let mut call2 = 0usize;
        let (r2, _) = eng
            .round(
                &mut target,
                &[RoundInput { slot: 0, pos: 7, last: 99 }],
                |_, toks, pos| {
                    // nothing pending after a rollback: the first feed is
                    // `last` itself, at the draft's rolled-back position
                    if call2 == 0 {
                        assert_eq!((toks[0], pos[0]), (99, 7));
                    }
                    Ok(fake_draft(&cfg, &mut call2))
                },
                |_, _, pos, klen| {
                    assert_eq!((pos[0], klen[0]), (7, 4));
                    Ok(fake_verify(&cfg))
                },
            )
            .unwrap();
        assert_eq!(r2[0].committed, vec![10, 11, 99]);
        eng.release(0);
        assert!(!eng.tracks(0));
    }
}
