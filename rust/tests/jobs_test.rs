//! Integration tests for the durable experiment daemon: a submitted plan
//! graph survives a shutdown mid-run, resumes on the next boot through the
//! content-addressed stage cache, and a fully-cached resubmission completes
//! with ZERO backend executions and aggregates bitwise-identical to a
//! direct uninterrupted `Executor::run_graph` of the same graph.  A second
//! test drives the whole `/jobs` HTTP surface end-to-end over real TCP.

use std::sync::Arc;
use std::time::{Duration, Instant};

use perp::config::ExperimentConfig;
use perp::jobs::{JobManager, JobRecord, JobRunner, JobSpec, JobStatus, JobStore, NodeStatus};
use perp::pipeline::parse::parse_graph;
use perp::pipeline::Executor;
use perp::runtime::{Backend, NativeBackend};
use perp::server::{client, ServeState, Server};
use perp::util::json::Json;

/// Leaked so runner threads are `'static`: a failed assertion then simply
/// fails the test instead of deadlocking a `thread::scope` against a
/// runner parked on the queue condvar.
fn rt() -> &'static NativeBackend {
    Box::leak(Box::new(NativeBackend::new()))
}

/// Same dense shape family as graph_test.rs; a distinct `retrain_steps`
/// value namespaces this binary's stage keys away from other test binaries
/// sharing the temp cache naming scheme.
fn cfg(retrain_steps: u64) -> ExperimentConfig {
    let mut c = ExperimentConfig::quick("gpt-nano");
    c.pretrain_steps = 120;
    c.retrain_steps = retrain_steps;
    c.recon_steps = 6;
    c.calib_seqs = 8;
    c.items_per_task = 6;
    c.eval_batches = 2;
    c
}

fn tmp(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn spec(stages: &str, cfg: &ExperimentConfig) -> JobSpec {
    JobSpec {
        name: "jobs-test".to_string(),
        graph: parse_graph("jobs-test", stages).unwrap(),
        cfg: cfg.clone(),
        seed: 0,
        jobs: 1,
    }
}

/// One daemon "boot": run a single `JobRunner` until `until(record)` holds
/// (polled from the durable store every 25ms), then begin graceful
/// shutdown and join the runner.
fn run_until(
    rt: &'static NativeBackend,
    cache: &std::path::Path,
    mgr: &Arc<JobManager>,
    id: &str,
    until: impl Fn(&JobRecord) -> bool,
) {
    let runner = JobRunner::new(rt, cache.to_path_buf(), mgr.clone());
    let h = std::thread::spawn(move || runner.run());
    let deadline = Instant::now() + Duration::from_secs(600);
    let mut timed_out = false;
    loop {
        if let Ok(rec) = mgr.store().load(id) {
            if until(&rec) {
                break;
            }
        }
        if Instant::now() >= deadline {
            timed_out = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    mgr.begin_shutdown();
    h.join().unwrap();
    assert!(!timed_out, "timed out waiting on job {id}");
}

#[test]
fn job_survives_interrupt_resumes_and_replays_from_cache() {
    let rt = rt();
    let out = tmp("perp_jobs_resume");
    let jobs_root = out.join("jobs");
    let cache = out.join("cache");
    let c = cfg(31);
    let stages = "prune(magnitude,0.55)|eval(ppl)|seeds(2)|agg";

    // boot 1: submit, let at least one node commit, then shut down mid-run
    let id = {
        let mgr = Arc::new(JobManager::open(&jobs_root).unwrap());
        let id = mgr.submit(spec(stages, &c)).unwrap();
        run_until(rt, &cache, &mgr, &id, |r| r.nodes_done() >= 1);
        id
    };
    let store = JobStore::open(&jobs_root).unwrap();
    let rec = store.load(&id).unwrap();
    assert_eq!(rec.status, JobStatus::Queued, "interrupted job requeues itself");
    assert_eq!(rec.attempts, 1);
    assert!(
        rec.warnings.iter().any(|w| w.contains("interrupted by daemon shutdown")),
        "{:?}",
        rec.warnings
    );
    assert!(rec.nodes_done() >= 1, "progress persisted before the interrupt");
    assert!(
        rec.nodes.values().all(|n| n.status != NodeStatus::Running),
        "running nodes reset to pending for the next attempt"
    );
    assert!(rec.backend_execs > 0, "attempt 1 did real work");
    assert!(rec.queue_wait_s.is_some());

    // boot 2: rescan requeues the job; it resumes and completes
    {
        let mgr = Arc::new(JobManager::open(&jobs_root).unwrap());
        run_until(rt, &cache, &mgr, &id, |r| r.status.is_terminal());
    }
    let rec = store.load(&id).unwrap();
    assert_eq!(rec.status, JobStatus::Done, "resume failed: {:?}", rec.error);
    assert_eq!(rec.attempts, 2);
    assert_eq!(rec.nodes.len(), 6, "2 seeds x (pretrain|prune|eval)");
    assert_eq!(rec.nodes_done(), 6);
    assert!(
        rec.nodes.values().any(|n| n.cache_hit),
        "nodes computed before the interrupt re-report as cache hits"
    );
    assert_eq!(rec.aggregates.len(), 1);
    let resumed_agg = rec.aggregates[0].clone();

    // boot 3: an identical resubmission replays fully from cache — zero
    // backend executions, every node a hit
    let execs_before = rt.exec_count();
    let id2 = {
        let mgr = Arc::new(JobManager::open(&jobs_root).unwrap());
        let id2 = mgr.submit(spec(stages, &c)).unwrap();
        run_until(rt, &cache, &mgr, &id2, |r| r.status.is_terminal());
        id2
    };
    assert_eq!(rt.exec_count(), execs_before, "a cached job must execute no backend graph");
    let rec2 = store.load(&id2).unwrap();
    assert_eq!(rec2.status, JobStatus::Done, "{:?}", rec2.error);
    assert_eq!(rec2.backend_execs, 0);
    assert!(rec2.nodes.values().all(|n| n.cache_hit && n.status == NodeStatus::Done));

    // aggregates (both the resumed job's and the replayed job's, which
    // round-tripped through job.json) are bitwise-identical to a direct
    // uninterrupted run of the same graph in a FRESH cache
    let direct_dir = tmp("perp_jobs_direct");
    let g = parse_graph("jobs-test", stages).unwrap();
    let direct = Executor::new(rt, c.clone(), direct_dir.clone(), 0)
        .quiet(true)
        .run_graph(&g)
        .unwrap();
    assert_eq!(direct.aggregates.len(), 1);
    let da = &direct.aggregates[0];
    for agg in [&resumed_agg, &rec2.aggregates[0]] {
        assert_eq!(agg.ppl.mean.to_bits(), da.ppl.mean.to_bits(), "ppl mean differs");
        assert_eq!(agg.ppl.std.to_bits(), da.ppl.std.to_bits(), "ppl std differs");
        assert_eq!(agg.ppl.n, da.ppl.n);
        assert_eq!(agg.sparsity.mean.to_bits(), da.sparsity.mean.to_bits());
        assert_eq!(agg.acc.mean.is_nan(), da.acc.mean.is_nan());
        if !da.acc.mean.is_nan() {
            assert_eq!(agg.acc.mean.to_bits(), da.acc.mean.to_bits());
        }
    }

    std::fs::remove_dir_all(&out).ok();
    std::fs::remove_dir_all(&direct_dir).ok();
}

#[test]
fn http_api_submits_executes_cancels_and_shuts_down() {
    let rt = rt();
    let out = tmp("perp_jobs_http");
    let jobs_root = out.join("jobs");
    let cache = out.join("cache");
    let c = cfg(32);

    let mgr = Arc::new(JobManager::open(&jobs_root).unwrap());
    let state = Arc::new(ServeState::new("gpt-nano".to_string(), c, cache.clone(), 0));
    state.set_jobs(mgr.clone());
    let server = Server::bind(state, "127.0.0.1:0", 2).unwrap();
    let addr = server.addr;
    let handle = server.spawn();
    let runner = JobRunner::new(rt, cache.clone(), mgr.clone());
    let h = std::thread::spawn(move || runner.run());

    // structured errors carry error/detail/status
    let (code, body) = client::get(addr, "/jobs/j9999").unwrap();
    assert_eq!(code, 404);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("error").and_then(Json::as_str), Some("no such job"));
    assert!(j.get("detail").and_then(Json::as_str).is_some());
    assert_eq!(j.get("status").and_then(Json::as_i64), Some(404));

    // a bad submit is a 400, never a persisted job
    let bad = Json::parse(r#"{"stages": "explode(now)"}"#).unwrap();
    let (code, resp) = client::post_json(addr, "/jobs", &bad).unwrap();
    assert_eq!(code, 400);
    assert_eq!(resp.get("error").and_then(Json::as_str), Some("invalid job"));

    // submit a tiny graph (pretrain + eval) against the daemon config
    let body = Json::obj(vec![
        ("stages", Json::Str("eval(ppl)".to_string())),
        ("name", Json::Str("smoke".to_string())),
    ]);
    let (code, resp) = client::post_json(addr, "/jobs", &body).unwrap();
    assert_eq!(code, 200, "{resp}");
    assert_eq!(resp.get("status").and_then(Json::as_str), Some("queued"));
    let id = resp.get("id").and_then(Json::as_str).unwrap().to_string();

    // a second job can be cancelled through the API while the first
    // occupies the single runner
    let doomed = Json::obj(vec![("stages", Json::Str("eval(ppl)|seeds(2)".to_string()))]);
    let (code, resp) = client::post_json(addr, "/jobs", &doomed).unwrap();
    assert_eq!(code, 200, "{resp}");
    let doomed_id = resp.get("id").and_then(Json::as_str).unwrap().to_string();
    let (code, resp) =
        client::post_json(addr, &format!("/jobs/{doomed_id}/cancel"), &Json::obj(vec![])).unwrap();
    assert_eq!(code, 200, "{resp}");
    let result = resp.get("result").and_then(Json::as_str).unwrap();
    assert!(result == "cancelled" || result == "cancelling", "{result}");

    // the listing shows both
    let (code, body) = client::get(addr, "/jobs").unwrap();
    assert_eq!(code, 200);
    assert!(body.contains(&id) && body.contains(&doomed_id), "{body}");

    // poll the detail endpoint until the first job completes
    let deadline = Instant::now() + Duration::from_secs(600);
    loop {
        let (code, body) = client::get(addr, &format!("/jobs/{id}")).unwrap();
        assert_eq!(code, 200);
        let j = Json::parse(&body).unwrap();
        match j.get("status").and_then(Json::as_str) {
            Some("done") => {
                let nodes = j.get("nodes").and_then(Json::as_obj).unwrap();
                assert_eq!(nodes.len(), 2, "pretrain + eval");
                assert!(nodes
                    .values()
                    .all(|n| n.get("status").and_then(Json::as_str) == Some("done")));
                break;
            }
            Some("failed") | Some("cancelled") => panic!("job ended badly: {body}"),
            _ => std::thread::sleep(Duration::from_millis(50)),
        }
        assert!(Instant::now() < deadline, "job did not finish in time");
    }

    // /metrics exposes the job families next to the serve metrics
    let (code, metrics) = client::get(addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    for family in [
        "perp_obs_counter_total{name=\"jobs.submitted\"}",
        "perp_obs_gauge{name=\"jobs.queued\"}",
        "perp_obs_gauge{name=\"jobs.running\"}",
        "perp_obs_histogram_count{name=\"jobs.queue_wait_s\"}",
    ] {
        assert!(metrics.contains(family), "missing {family} in:\n{metrics}");
    }

    // graceful stop over HTTP: the accept loop exits and the runner drains
    // (any still-running job requeues itself for the next boot)
    let (code, resp) = client::post_json(addr, "/shutdown", &Json::obj(vec![])).unwrap();
    assert_eq!(code, 200, "{resp}");
    h.join().unwrap();
    handle.join();
    std::fs::remove_dir_all(&out).ok();
}
