//! Dense linear algebra on host tensors.
//!
//! The load-bearing consumer is SparseGPT's OBS solver
//! (`pruning::sparsegpt`), which needs the exact Frantar & Alistarh Cholesky
//! toolchain:
//!
//! 1. `cholesky(H)`            — lower factor L, H = L Lᵀ (with damping by
//!    the caller);
//! 2. `cholesky_inverse(L)`    — H⁻¹ from the factor;
//! 3. transpose of `cholesky(H⁻¹)` — the upper-triangular "Hinv" whose rows
//!    drive the column-wise error compensation.
//!
//! Matmul is a rayon-parallel, cache-blocked (i/j/k) kernel — the NativeBackend
//! hot path as well as the calibration-scale Gram builder.  The single-thread
//! `*_serial` variants are kept as the bench baselines (`runtime_micro`).

use rayon::prelude::*;

use super::{pool, Tensor};

/// Row-block size each rayon task owns.
const BI: usize = 32;
/// Column tile width (j blocking): one output tile row stays in L1.
const BJ: usize = 256;
/// Inner-dim tile (k blocking): the A-row segment is reused across j tiles.
const BK: usize = 64;

/// a:(n,k) @ b:(k,m) -> (n,m); rayon-parallel over row blocks, blocked over
/// i/j/k.  Exact zeros in `a` are skipped — masked/sparse operands get the
/// axpy for free.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (k2, m) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner-dim mismatch {k} vs {k2}");
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    let bd = b.data();
    out.par_chunks_mut(BI * m).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * BI;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..m).step_by(BJ) {
                let j1 = (j0 + BJ).min(m);
                for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                    let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                    let otile = &mut orow[j0..j1];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let btile = &bd[kk * m + j0..kk * m + j1];
                        for (o, &bv) in otile.iter_mut().zip(btile) {
                            *o += av * bv;
                        }
                    }
                }
            }
        }
    });
    Tensor::new(&[n, m], out)
}

/// Single-thread reference kernel (the pre-rayon implementation); kept for
/// the `runtime_micro` speedup comparison and as a fallback oracle.
pub fn matmul_serial(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (k2, m) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner-dim mismatch {k} vs {k2}");
    let mut out = vec![0.0f32; n * m];
    let ad = a.data();
    let bd = b.data();
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..n {
            let arow = &ad[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
    Tensor::new(&[n, m], out)
}

/// a:(n,k) @ b:(m,k)ᵀ -> (n,m) — the (out,in)-weight-layout forward.
/// Both operands are read row-major (sequential dots); rayon over row blocks
/// with j tiling so a B-row block stays cached across the i rows of a block.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (m, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner-dim mismatch {k} vs {k2}");
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    let bd = b.data();
    out.par_chunks_mut(BI * m).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * BI;
        for j0 in (0..m).step_by(64) {
            let j1 = (j0 + 64).min(m);
            for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                for j in j0..j1 {
                    let brow = &bd[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        acc += arow[kk] * brow[kk];
                    }
                    orow[j] = acc;
                }
            }
        }
    });
    Tensor::new(&[n, m], out)
}

/// a:(n,k) @ (b ⊙ mask):(m,k)ᵀ -> (n,m) **without materialising** b ⊙ mask —
/// the masked-linear forward.  Pruned entries (mask == 0) are skipped inside
/// the dot product, so sparsity pays at read time and no (m,k) scratch
/// buffer is allocated/written per call (the old path built W⊙M first).
/// `mask` must be binary and shaped like `b`.
pub fn matmul_nt_masked(a: &Tensor, b: &Tensor, mask: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (m, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt_masked inner-dim mismatch {k} vs {k2}");
    assert_eq!(mask.shape(), b.shape(), "mask must be shaped like b");
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    let bd = b.data();
    let md = mask.data();
    out.par_chunks_mut(BI * m).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * BI;
        for j0 in (0..m).step_by(64) {
            let j1 = (j0 + 64).min(m);
            for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                for j in j0..j1 {
                    let brow = &bd[j * k..(j + 1) * k];
                    let mrow = &md[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        if mrow[kk] == 0.0 {
                            continue; // pruned weight: skipped, not multiplied
                        }
                        acc += arow[kk] * brow[kk];
                    }
                    orow[j] = acc;
                }
            }
        }
    });
    Tensor::new(&[n, m], out)
}

/// a:(n,m) @ (b ⊙ mask):(m,k) -> (n,k) without materialising b ⊙ mask — the
/// masked-linear backward dx.  Skips exact zeros of `a` (like [`matmul`])
/// and gates each b-row element by the mask.
pub fn matmul_masked(a: &Tensor, b: &Tensor, mask: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (k2, m) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_masked inner-dim mismatch {k} vs {k2}");
    assert_eq!(mask.shape(), b.shape(), "mask must be shaped like b");
    let mut out = pool::zeroed(n * m);
    let ad = a.data();
    let bd = b.data();
    let md = mask.data();
    out.par_chunks_mut(BI * m).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * BI;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for j0 in (0..m).step_by(BJ) {
                let j1 = (j0 + BJ).min(m);
                for (ii, orow) in chunk.chunks_mut(m).enumerate() {
                    let arow = &ad[(i0 + ii) * k..(i0 + ii + 1) * k];
                    let otile = &mut orow[j0..j1];
                    for kk in k0..k1 {
                        let av = arow[kk];
                        if av == 0.0 {
                            continue;
                        }
                        let btile = &bd[kk * m + j0..kk * m + j1];
                        let mtile = &md[kk * m + j0..kk * m + j1];
                        for ((o, &bv), &mv) in otile.iter_mut().zip(btile).zip(mtile) {
                            *o += av * bv * mv;
                        }
                    }
                }
            }
        }
    });
    Tensor::new(&[n, m], out)
}

/// Single-thread reference of [`matmul_nt`] (bench baseline).
pub fn matmul_nt_serial(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = (a.rows(), a.cols());
    let (m, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; n * m];
    let ad = a.data();
    let bd = b.data();
    for i in 0..n {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..m {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            out[i * m + j] = acc;
        }
    }
    Tensor::new(&[n, m], out)
}

/// a:(n,m)ᵀ @ b:(n,k) -> (m,k) — the backward-pass contraction (dWᵀ = dYᵀ X,
/// Grams XᵀX).  Parallel over blocks of output rows; each task scans the
/// shared operands once, skipping exact zeros of the transposed column.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, m) = (a.rows(), a.cols());
    let (n2, k) = (b.rows(), b.cols());
    assert_eq!(n, n2, "matmul_tn outer-dim mismatch {n} vs {n2}");
    let mut out = pool::zeroed(m * k);
    let ad = a.data();
    let bd = b.data();
    out.par_chunks_mut(BI * k).enumerate().for_each(|(ci, chunk)| {
        let i0 = ci * BI;
        let rows = chunk.len() / k;
        for nn in 0..n {
            let acol = &ad[nn * m..(nn + 1) * m];
            let brow = &bd[nn * k..(nn + 1) * k];
            for ii in 0..rows {
                let av = acol[i0 + ii];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut chunk[ii * k..(ii + 1) * k];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
    Tensor::new(&[m, k], out)
}

#[derive(Debug, thiserror::Error)]
pub enum LinalgError {
    #[error("matrix not positive definite at pivot {0} (value {1})")]
    NotPd(usize, f64),
}

/// Lower-triangular Cholesky factor L with A = L Lᵀ.  A must be symmetric.
pub fn cholesky(a: &Tensor) -> Result<Tensor, LinalgError> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let mut l = vec![0.0f64; n * n];
    let ad = a.data();
    for i in 0..n {
        for j in 0..=i {
            let mut s = ad[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(LinalgError::NotPd(i, s));
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(Tensor::new(&[n, n], l.into_iter().map(|x| x as f32).collect()))
}

/// Solve L y = b (forward substitution), L lower triangular.
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let mut y = vec![0.0f64; n];
    let ld = l.data();
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= ld[i * n + k] as f64 * y[k];
        }
        y[i] = s / ld[i * n + i] as f64;
    }
    y.into_iter().map(|x| x as f32).collect()
}

/// Solve Lᵀ x = y (backward substitution), L lower triangular.
pub fn solve_lower_t(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let mut x = vec![0.0f64; n];
    let ld = l.data();
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in (i + 1)..n {
            s -= ld[k * n + i] as f64 * x[k];
        }
        x[i] = s / ld[i * n + i] as f64;
    }
    x.into_iter().map(|x| x as f32).collect()
}

/// A⁻¹ from the lower Cholesky factor (torch.cholesky_inverse analogue).
pub fn cholesky_inverse(l: &Tensor) -> Tensor {
    let n = l.rows();
    let mut inv = Tensor::zeros(&[n, n]);
    let mut e = vec![0.0f32; n];
    for col in 0..n {
        e[col] = 1.0;
        let y = solve_lower(l, &e);
        let x = solve_lower_t(l, &y);
        for row in 0..n {
            inv.set2(row, col, x[row]);
        }
        e[col] = 0.0;
    }
    // symmetrise against float drift
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (inv.at2(i, j) + inv.at2(j, i));
            inv.set2(i, j, v);
            inv.set2(j, i, v);
        }
    }
    inv
}

/// SparseGPT's preprocessing: given a (possibly singular) Gram matrix H,
/// apply percdamp-style damping and return the **upper** Cholesky factor of
/// H⁻¹ — rows of this factor drive the OBS column updates.
///
/// Dead inputs (zero diagonal) get a unit diagonal, matching the reference
/// implementation's handling.
pub fn sparsegpt_hinv(h: &Tensor, percdamp: f64) -> Tensor {
    let n = h.rows();
    let mut hd = h.clone();
    let mean_diag: f64 =
        (0..n).map(|i| hd.at2(i, i) as f64).sum::<f64>() / n as f64;
    let damp = (percdamp * mean_diag).max(1e-8) as f32;
    for i in 0..n {
        let d = hd.at2(i, i);
        if d == 0.0 {
            hd.set2(i, i, 1.0);
        } else {
            hd.set2(i, i, d + damp);
        }
    }
    // chol(H) -> H^-1 -> chol(H^-1) upper
    let mut boost = damp;
    let l = loop {
        match cholesky(&hd) {
            Ok(l) => break l,
            Err(_) => {
                // escalate damping until PD (mirrors practical SparseGPT forks)
                boost *= 10.0;
                for i in 0..n {
                    hd.set2(i, i, hd.at2(i, i) + boost);
                }
            }
        }
    };
    let hinv = cholesky_inverse(&l);
    let linv = cholesky(&hinv).expect("inverse of PD matrix is PD");
    linv.transpose2() // upper triangular U with H⁻¹ = Uᵀ U ... (rowwise use)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Tensor {
        let a = Tensor::randn(&[n, n], 1.0, rng);
        let mut h = matmul_nt(&a, &a); // A Aᵀ is PSD
        for i in 0..n {
            h.set2(i, i, h.at2(i, i) + 0.5);
        }
        h
    }

    #[test]
    fn matmul_small_exact() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_nt_matches_matmul() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let b = Tensor::randn(&[4, 5], 1.0, &mut rng);
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &b.transpose2());
        assert!(c1.allclose(&c2, 1e-5, 1e-5));
    }

    #[test]
    fn parallel_kernels_match_serial() {
        let mut rng = Rng::new(8);
        // sizes straddling the block boundaries, incl. non-multiples
        for (n, k, m) in [(1, 1, 1), (33, 65, 31), (70, 130, 257), (128, 64, 64)] {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, m], 1.0, &mut rng);
            let bt = Tensor::randn(&[m, k], 1.0, &mut rng);
            assert!(matmul(&a, &b).allclose(&matmul_serial(&a, &b), 1e-4, 1e-4));
            assert!(matmul_nt(&a, &bt).allclose(&matmul_nt_serial(&a, &bt), 1e-4, 1e-4));
        }
    }

    #[test]
    fn masked_kernels_match_materialised_reference() {
        let mut rng = Rng::new(21);
        for (n, k, m) in [(1usize, 1usize, 1usize), (33, 65, 31), (70, 130, 257)] {
            let a = Tensor::randn(&[n, k], 1.0, &mut rng);
            let w = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mask = Tensor::randn(&[m, k], 1.0, &mut rng)
                .map(|v| if v > 0.0 { 1.0 } else { 0.0 });
            let wm = w.hadamard(&mask);
            // fused forward == materialise-then-matmul_nt
            let fused = matmul_nt_masked(&a, &w, &mask);
            assert!(fused.allclose(&matmul_nt(&a, &wm), 1e-4, 1e-4), "{n}x{k}x{m}");
            // fused backward dx == materialise-then-matmul (dy:(n,m) @ (m,k))
            let dy = Tensor::randn(&[n, m], 1.0, &mut rng);
            let fused_dx = matmul_masked(&dy, &w, &mask);
            let ref_dx = matmul(&dy, &wm);
            assert!(fused_dx.allclose(&ref_dx, 1e-4, 1e-4), "{n}x{k}x{m} dx");
        }
    }

    #[test]
    fn masked_kernels_dense_mask_is_identity() {
        let mut rng = Rng::new(22);
        let a = Tensor::randn(&[9, 17], 1.0, &mut rng);
        let w = Tensor::randn(&[13, 17], 1.0, &mut rng);
        let ones = Tensor::ones(&[13, 17]);
        assert!(matmul_nt_masked(&a, &w, &ones).allclose(&matmul_nt(&a, &w), 1e-5, 1e-5));
        let b = Tensor::randn(&[17, 13], 1.0, &mut rng);
        let ones_b = Tensor::ones(&[17, 13]);
        assert!(matmul_masked(&a, &b, &ones_b).allclose(&matmul(&a, &b), 1e-5, 1e-5));
    }

    #[test]
    fn matmul_tn_is_transposed_product() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[37, 19], 1.0, &mut rng);
        let b = Tensor::randn(&[37, 23], 1.0, &mut rng);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&a.transpose2(), &b);
        assert_eq!(c1.shape(), &[19, 23]);
        assert!(c1.allclose(&c2, 1e-4, 1e-4));
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(4);
        let h = random_spd(12, &mut rng);
        let l = cholesky(&h).unwrap();
        let rec = matmul_nt(&l, &l);
        assert!(rec.allclose(&h, 1e-3, 1e-4), "LLᵀ != H");
        // lower triangular
        for i in 0..12 {
            for j in (i + 1)..12 {
                assert_eq!(l.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Tensor::new(&[2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalue -1
        assert!(cholesky(&m).is_err());
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Rng::new(5);
        let h = random_spd(9, &mut rng);
        let l = cholesky(&h).unwrap();
        let b: Vec<f32> = (0..9).map(|i| i as f32 - 4.0).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // check H x = b
        let hx = matmul(&h, &Tensor::new(&[9, 1], x));
        for i in 0..9 {
            assert!((hx.data()[i] - b[i]).abs() < 1e-3, "{i}");
        }
    }

    #[test]
    fn cholesky_inverse_is_inverse() {
        let mut rng = Rng::new(6);
        let h = random_spd(10, &mut rng);
        let l = cholesky(&h).unwrap();
        let inv = cholesky_inverse(&l);
        let prod = matmul(&h, &inv);
        assert!(prod.allclose(&Tensor::eye(10), 1e-3, 1e-4), "H·H⁻¹ != I");
    }

    #[test]
    fn sparsegpt_hinv_properties() {
        let mut rng = Rng::new(7);
        let h = random_spd(8, &mut rng);
        let u = sparsegpt_hinv(&h, 0.01);
        // upper triangular with positive diagonal
        for i in 0..8 {
            assert!(u.at2(i, i) > 0.0);
            for j in 0..i {
                assert_eq!(u.at2(i, j), 0.0);
            }
        }
    }

    #[test]
    fn sparsegpt_hinv_handles_dead_inputs() {
        // a Gram with an all-zero row/col (dead feature) must not blow up
        let mut h = Tensor::eye(5);
        h.set2(2, 2, 0.0);
        let u = sparsegpt_hinv(&h, 0.01);
        assert!(u.data().iter().all(|x| x.is_finite()));
    }
}
