//! Hand-rolled HTTP/1.1 codec and the endpoint routing table.
//!
//! Zero-dependency by design (std `TcpStream` only): persistent
//! connections per HTTP/1.1 defaults (`Connection: keep-alive` honored;
//! clients opt out with `Connection: close`), bodies bounded by
//! `Content-Length`, JSON in/out through [`crate::util::json::Json`].
//! Idle keep-alive connections are reaped after
//! [`KEEP_ALIVE_IDLE_SECS`], polled in one-second slices so shutdown is
//! never held hostage by a parked socket.  Endpoints:
//!
//! | route               | verb | body                                        |
//! |---------------------|------|---------------------------------------------|
//! | `/healthz`          | GET  | status + loaded variants                    |
//! | `/metrics`          | GET  | Prometheus text exposition                  |
//! | `/models`           | GET  | per-variant detail (params, sparsity, KV)   |
//! | `/models/load`      | POST | `{name, checkpoint[, model, max_active, draft, spec_k]}` |
//! | `/generate`         | POST | `{prompt[, model, max_tokens, temperature]}`|
//! | `/score`            | POST | `{text[, model]}`                           |
//! | `/jobs`             | POST | submit a plan graph (see [`crate::jobs::api`]) |
//! | `/jobs`             | GET  | job summaries                               |
//! | `/jobs/<id>`        | GET  | full job record (per-node status, aggregates) |
//! | `/jobs/<id>/cancel` | POST | cancel queued/running job                   |
//! | `/shutdown`         | POST | graceful shutdown (daemon requeues jobs; loopback peers only) |
//!
//! Errors are uniform JSON: `{"error": <short>, "detail": <specifics>,
//! "status": <code>}` with the code mirrored in the HTTP status line.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::batcher::{self, BatchCfg, EngineSpec};
use super::ServeState;

// ---------------------------------------------------------------------------
// HTTP codec.
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: String,
    /// Did this connection come from a loopback address?  Process-control
    /// endpoints (`POST /shutdown`) are restricted to local peers so a
    /// `--host 0.0.0.0` bind doesn't hand remote clients a process kill.
    pub peer_loopback: bool,
    /// HTTP/1.1 default: the connection persists unless the client sent
    /// `Connection: close`.
    pub keep_alive: bool,
}

const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;
/// Seconds a keep-alive connection may sit idle between requests before
/// the worker reclaims it.
const KEEP_ALIVE_IDLE_SECS: usize = 30;

pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        let n = stream.read(&mut tmp).context("reading request head")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.extend_from_slice(&tmp[..n]);
        if let Some(pos) = find(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request head too large");
        }
    };
    let head = std::str::from_utf8(&buf[..header_end]).context("non-utf8 request head")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().context("empty request")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_ascii_uppercase();
    let path = parts.next().context("missing path")?.to_string();
    let mut content_length = 0usize;
    let mut keep_alive = true;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            } else if k.trim().eq_ignore_ascii_case("connection") {
                keep_alive = !v.trim().eq_ignore_ascii_case("close");
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("request body too large");
    }
    let body_start = header_end + 4;
    while buf.len() < body_start + content_length {
        let n = stream.read(&mut tmp).context("reading request body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let body =
        String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    let peer_loopback = stream.peer_addr().map(|a| a.ip().is_loopback()).unwrap_or(false);
    Ok(Request { method, path, body, peer_loopback, keep_alive })
}

fn find(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())
}

/// What the between-requests idle wait observed.
enum Wait {
    /// Bytes arrived — another request is on the wire.
    Request,
    /// Peer closed, socket error, idle cap hit, or the server is stopping.
    Done,
}

/// Park between keep-alive requests in one-second `peek` slices, checking
/// the process stop flag each slice — a graceful shutdown never waits on
/// an idle connection, and a closed peer is noticed without issuing a
/// spurious 400.
fn wait_for_request(state: &ServeState, stream: &mut TcpStream) -> Wait {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(1)));
    let mut probe = [0u8; 1];
    for _ in 0..KEEP_ALIVE_IDLE_SECS {
        if state.stop.load(Ordering::Relaxed) {
            return Wait::Done;
        }
        match stream.peek(&mut probe) {
            Ok(0) => return Wait::Done, // clean close from the peer
            Ok(_) => return Wait::Request,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => return Wait::Done,
        }
    }
    Wait::Done // idle cap reached
}

/// One connection end-to-end: parse, route, respond — looping while the
/// client keeps the connection alive.
pub fn serve_connection(state: &ServeState, stream: &mut TcpStream) {
    let mut first = true;
    loop {
        // The first request follows the connect immediately; later ones
        // may be a while coming, so park stop-aware instead of letting
        // read_request time out into a 400.
        if !first {
            match wait_for_request(state, stream) {
                Wait::Request => {}
                Wait::Done => return,
            }
        }
        first = false;
        match read_request(stream) {
            Ok(req) => {
                state.http_requests.fetch_add(1, Ordering::Relaxed);
                let keep = req.keep_alive;
                let (status, ctype, body) = route(state, &req);
                if respond(stream, status, ctype, &body, keep).is_err() || !keep {
                    return;
                }
            }
            Err(e) => {
                let body = err_body(400, "bad request", &format!("{e:#}"));
                let _ = respond(stream, 400, "application/json", &body, false);
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Routing.
// ---------------------------------------------------------------------------

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; version=0.0.4";

/// Structured error body: a short machine-matchable `error`, the human
/// `detail`, and the HTTP `status` echoed for clients that drop headers.
fn err_body(status: u16, error: &str, detail: &str) -> String {
    Json::obj(vec![
        ("error", Json::Str(error.to_string())),
        ("detail", Json::Str(detail.to_string())),
        ("status", Json::Num(status as f64)),
    ])
    .to_string()
}

/// `(status, body)` error pair — every handler's failure path.
fn err(status: u16, error: &str, detail: &str) -> (u16, String) {
    (status, err_body(status, error, detail))
}

/// Prometheus label-value escaping (backslash, quote, newline).
fn label_escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Variant names live in URLs, JSON and metric labels — keep them boring.
fn valid_variant_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.' | ':' | '@'))
}

pub fn route(state: &ServeState, req: &Request) -> (u16, &'static str, String) {
    let json = |(status, body): (u16, String)| (status, JSON, body);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, JSON, healthz(state)),
        ("GET", "/metrics") => (200, TEXT, metrics(state)),
        ("GET", "/models") => (200, JSON, models(state)),
        ("POST", "/models/load") => json(models_load(state, &req.body)),
        ("POST", "/generate") => json(generate(state, &req.body)),
        ("POST", "/score") => json(score(state, &req.body)),
        ("POST", "/jobs") => json(jobs_submit(state, &req.body)),
        ("GET", "/jobs") => json(jobs_list(state)),
        ("POST", "/shutdown") => json(shutdown(state, req)),
        (method, path) if path.starts_with("/jobs/") => json(jobs_entry(state, method, path)),
        ("GET", _) | ("POST", _) => json(err(404, "not found", &format!("no route {}", req.path))),
        _ => json(err(405, "method not allowed", &format!("method {} not allowed", req.method))),
    }
}

fn healthz(state: &ServeState) -> String {
    Json::obj(vec![
        ("status", Json::Str("ok".to_string())),
        ("uptime_s", Json::Num(state.started.elapsed().as_secs_f64())),
        (
            "models",
            Json::Arr(state.names().into_iter().map(Json::Str).collect()),
        ),
    ])
    .to_string()
}

fn models(state: &ServeState) -> String {
    let entries: Vec<Json> = state
        .engines_snapshot()
        .into_iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.clone())),
                ("model", Json::Str(e.model.clone())),
                ("params", Json::Num(e.info.total_params as f64)),
                ("weight_sparsity", Json::Num(e.info.weight_sparsity)),
                ("slots", Json::Num(e.info.slots as f64)),
                ("max_active", Json::Num(e.info.max_active as f64)),
                ("seq_len", Json::Num(e.info.seq_len as f64)),
                ("kv_cache_bytes", Json::Num(e.info.kv_bytes as f64)),
                ("sparse_weight_bytes", Json::Num(e.info.sparse_bytes as f64)),
                (
                    "checkpoint",
                    e.info
                        .checkpoint
                        .clone()
                        .map(Json::Str)
                        .unwrap_or(Json::Null),
                ),
                (
                    "draft",
                    e.info.draft.clone().map(Json::Str).unwrap_or(Json::Null),
                ),
                ("draft_sparsity", Json::Num(e.info.draft_sparsity)),
                ("spec_k", Json::Num(e.info.spec_k as f64)),
            ])
        })
        .collect();
    Json::obj(vec![("models", Json::Arr(entries))]).to_string()
}

fn metrics(state: &ServeState) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "perp_serve_uptime_seconds {}\n",
        state.started.elapsed().as_secs_f64()
    ));
    out.push_str(&format!(
        "perp_serve_http_requests_total {}\n",
        state.http_requests.load(Ordering::Relaxed)
    ));
    for e in state.engines_snapshot() {
        let m = &e.metrics;
        let tag = format!("{{model=\"{}\"}}", label_escape(&e.name));
        let rows: [(&str, u64); 8] = [
            ("requests_total", m.requests.load(Ordering::Relaxed)),
            ("completed_total", m.completed.load(Ordering::Relaxed)),
            ("generated_tokens_total", m.gen_tokens.load(Ordering::Relaxed)),
            ("prefill_batches_total", m.prefills.load(Ordering::Relaxed)),
            ("decode_steps_total", m.decode_steps.load(Ordering::Relaxed)),
            ("queue_depth", m.queued.load(Ordering::Relaxed)),
            ("active_streams", m.active.load(Ordering::Relaxed)),
            ("peak_active_streams", m.peak_active.load(Ordering::Relaxed)),
        ];
        for (name, value) in rows {
            out.push_str(&format!("perp_serve_{name}{tag} {value}\n"));
        }
        out.push_str(&format!(
            "perp_serve_kv_cache_bytes{tag} {}\n",
            e.info.kv_bytes
        ));
        out.push_str(&format!(
            "perp_serve_sparse_weight_bytes{tag} {}\n",
            e.info.sparse_bytes
        ));
        // speculative-decoding families, present only on engines with a
        // draft loaded (acceptance rate = accepted / proposed)
        if e.info.spec_k > 0 {
            let srows: [(&str, u64); 6] = [
                ("rounds_total", m.spec_rounds.load(Ordering::Relaxed)),
                ("draft_steps_total", m.spec_draft_steps.load(Ordering::Relaxed)),
                ("proposed_total", m.spec_proposed.load(Ordering::Relaxed)),
                ("accepted_total", m.spec_accepted.load(Ordering::Relaxed)),
                ("rejected_total", m.spec_rejected.load(Ordering::Relaxed)),
                ("rollbacks_total", m.spec_rollbacks.load(Ordering::Relaxed)),
            ];
            for (name, value) in srows {
                out.push_str(&format!("perp_obs_spec_{name}{tag} {value}\n"));
            }
            out.push_str(&format!("perp_obs_spec_k{tag} {}\n", e.info.spec_k));
        }
    }
    // process-wide obs registry: backend exec counts, SpMM layout dispatch,
    // tape-pool reuse, queue-wait / batch-fill / KV-occupancy histograms
    out.push_str(&crate::obs::counters::Registry::global().render_prometheus());
    out
}

fn generate(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return err(400, "bad json", &e.to_string()),
    };
    let Some(prompt) = j.get("prompt").and_then(Json::as_str) else {
        return err(400, "missing field", "\"prompt\" is required");
    };
    let model = j.str_or("model", &state.default_model);
    let max_new = j.get("max_tokens").and_then(Json::as_usize);
    let temperature = j.get("temperature").and_then(Json::as_f64).unwrap_or(0.0) as f32;
    let Some(engine) = state.engine(&model) else {
        return err(404, "unknown model", &format!("no model variant {model:?}"));
    };
    let t0 = Instant::now();
    match engine.generate(prompt.to_string(), max_new, temperature) {
        Ok(r) => (
            200,
            Json::obj(vec![
                ("model", Json::Str(model)),
                ("completion", Json::Str(r.completion)),
                (
                    "tokens",
                    Json::Arr(r.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                ("prompt_tokens", Json::Num(r.prompt_tokens as f64)),
                ("finish_reason", Json::Str(r.finish.to_string())),
                ("latency_ms", Json::Num(t0.elapsed().as_secs_f64() * 1e3)),
            ])
            .to_string(),
        ),
        Err(e) => err(500, "generation failed", &format!("{e:#}")),
    }
}

fn score(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return err(400, "bad json", &e.to_string()),
    };
    let Some(text) = j.get("text").and_then(Json::as_str) else {
        return err(400, "missing field", "\"text\" is required");
    };
    let model = j.str_or("model", &state.default_model);
    let Some(engine) = state.engine(&model) else {
        return err(404, "unknown model", &format!("no model variant {model:?}"));
    };
    match engine.score(text.to_string()) {
        Ok(r) => (
            200,
            Json::obj(vec![
                ("model", Json::Str(model)),
                ("nll", Json::Num(r.nll)),
                ("ppl", Json::Num(r.ppl)),
                ("tokens", Json::Num(r.tokens as f64)),
            ])
            .to_string(),
        ),
        Err(e) => err(400, "scoring failed", &format!("{e:#}")),
    }
}

/// Hot-load another checkpoint variant behind the running process.
fn models_load(state: &ServeState, body: &str) -> (u16, String) {
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return err(400, "bad json", &e.to_string()),
    };
    let Some(name) = j.get("name").and_then(Json::as_str) else {
        return err(400, "missing field", "\"name\" is required");
    };
    if !valid_variant_name(name) {
        return err(
            400,
            "invalid name",
            "\"name\" must be 1-64 chars of [A-Za-z0-9._:@-]",
        );
    }
    let Some(ckpt) = j.get("checkpoint").and_then(Json::as_str) else {
        return err(400, "missing field", "\"checkpoint\" is required");
    };
    if state.engine(name).is_some() {
        return err(409, "conflict", &format!("variant {name:?} already loaded"));
    }
    let mut cfg = state.base_cfg.clone();
    if let Some(m) = j.get("model").and_then(Json::as_str) {
        cfg.model = m.to_string();
    }
    let mut batch = BatchCfg::default();
    if let Some(a) = j.get("max_active").and_then(Json::as_usize) {
        batch.max_active = a;
    }
    // optional speculative decoding: a draft checkpoint plus draft length
    let draft = j.get("draft").and_then(Json::as_str).map(PathBuf::from);
    let spec_k = j.get("spec_k").and_then(Json::as_usize).unwrap_or(4);
    if spec_k == 0 {
        return err(400, "invalid spec_k", "\"spec_k\" must be >= 1");
    }
    let spec = EngineSpec {
        name: name.to_string(),
        cfg,
        seed: state.seed,
        checkpoint: Some(PathBuf::from(ckpt)),
        cache_dir: state.cache_dir.clone(),
        batch,
        draft,
        spec_k,
    };
    match batcher::spawn(spec) {
        Ok(handle) => match state.insert(handle) {
            Ok(()) => (
                200,
                Json::obj(vec![("loaded", Json::Str(name.to_string()))]).to_string(),
            ),
            Err(e) => err(409, "conflict", &format!("{e:#}")),
        },
        Err(e) => err(400, "load failed", &format!("{e:#}")),
    }
}

// ---------------------------------------------------------------------------
// Job queue endpoints (daemon mode).
// ---------------------------------------------------------------------------

/// The daemon's queue, or a 503 for plain `repro serve`.
fn jobs_manager(
    state: &ServeState,
) -> Result<&std::sync::Arc<crate::jobs::JobManager>, (u16, String)> {
    state.jobs().ok_or_else(|| {
        err(
            503,
            "no job queue",
            "this server has no job queue; start one with `repro daemon`",
        )
    })
}

fn jobs_submit(state: &ServeState, body: &str) -> (u16, String) {
    let mgr = match jobs_manager(state) {
        Ok(m) => m,
        Err(e) => return e,
    };
    let j = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => return err(400, "bad json", &e.to_string()),
    };
    let spec = match crate::jobs::api::parse_submit(&j, &state.base_cfg, state.seed) {
        Ok(s) => s,
        Err(e) => return err(400, "invalid job", &format!("{e:#}")),
    };
    match mgr.submit(spec) {
        Ok(id) => (
            200,
            Json::obj(vec![
                ("id", Json::Str(id)),
                ("status", Json::Str("queued".to_string())),
            ])
            .to_string(),
        ),
        Err(e) => err(503, "submit failed", &format!("{e:#}")),
    }
}

fn jobs_list(state: &ServeState) -> (u16, String) {
    let mgr = match jobs_manager(state) {
        Ok(m) => m,
        Err(e) => return e,
    };
    match mgr.store().list() {
        Ok(recs) => (
            200,
            Json::obj(vec![(
                "jobs",
                Json::Arr(recs.iter().map(crate::jobs::api::job_summary).collect()),
            )])
            .to_string(),
        ),
        Err(e) => err(500, "store error", &format!("{e:#}")),
    }
}

/// `/jobs/<id>` and `/jobs/<id>/cancel`.
fn jobs_entry(state: &ServeState, method: &str, path: &str) -> (u16, String) {
    let mgr = match jobs_manager(state) {
        Ok(m) => m,
        Err(e) => return e,
    };
    let rest = path.trim_start_matches("/jobs/");
    let (id, action) = match rest.split_once('/') {
        None => (rest, None),
        Some((id, act)) => (id, Some(act)),
    };
    if id.is_empty() || !id.chars().all(|c| c.is_ascii_alphanumeric()) {
        return err(400, "invalid job id", &format!("malformed job id {id:?}"));
    }
    let rec = match mgr.store().load(id) {
        Ok(r) => r,
        Err(_) => return err(404, "no such job", &format!("job {id:?} not found")),
    };
    match (method, action) {
        ("GET", None) => (200, crate::jobs::api::job_detail(&rec).to_string()),
        ("POST", Some("cancel")) => match mgr.cancel(id) {
            Ok(outcome) => (
                200,
                Json::obj(vec![
                    ("id", Json::Str(id.to_string())),
                    ("result", Json::Str(outcome.to_string())),
                ])
                .to_string(),
            ),
            Err(e) => err(409, "cannot cancel", &format!("{e:#}")),
        },
        ("GET", Some(a)) | ("POST", Some(a)) => {
            err(404, "not found", &format!("no job action {a:?}"))
        }
        _ => err(405, "method not allowed", &format!("{method} {path}")),
    }
}

/// Graceful process shutdown over HTTP (the daemon's counterpart to
/// SIGINT/SIGTERM): stop dequeuing, requeue in-flight jobs, stop serving.
/// Loopback-only — a wide `--host` bind must not expose remote process
/// kill; remote operators use signals on the daemon host instead.
fn shutdown(state: &ServeState, req: &Request) -> (u16, String) {
    if !req.peer_loopback {
        return err(
            403,
            "forbidden",
            "POST /shutdown is restricted to loopback peers; signal the daemon process instead",
        );
    }
    super::request_shutdown(state);
    (
        200,
        Json::obj(vec![("status", Json::Str("shutting down".to_string()))]).to_string(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subslice_finder() {
        assert_eq!(find(b"abc\r\n\r\nxyz", b"\r\n\r\n"), Some(3));
        assert_eq!(find(b"abc", b"\r\n\r\n"), None);
    }

    #[test]
    fn error_bodies_are_structured_json() {
        let b = err_body(404, "no such job", "boom \"quoted\"");
        let j = Json::parse(&b).unwrap();
        assert_eq!(j.req("error").as_str().unwrap(), "no such job");
        assert_eq!(j.req("detail").as_str().unwrap(), "boom \"quoted\"");
        assert_eq!(j.req("status").as_i64().unwrap(), 404);
        let (status, body) = err(405, "method not allowed", "PATCH /jobs");
        assert_eq!(status, 405);
        assert!(body.contains("\"status\": 405") || body.contains("\"status\":405"), "{body}");
    }

    #[test]
    fn shutdown_is_loopback_only() {
        let state = ServeState::new(
            "gpt-nano".to_string(),
            crate::config::ExperimentConfig::quick("gpt-nano"),
            std::env::temp_dir().join("perp_router_shutdown_test"),
            0,
        );
        let req = |loopback: bool| Request {
            method: "POST".to_string(),
            path: "/shutdown".to_string(),
            body: String::new(),
            peer_loopback: loopback,
            keep_alive: false,
        };
        let (status, _, body) = route(&state, &req(false));
        assert_eq!(status, 403, "{body}");
        assert!(!state.stop.load(Ordering::Relaxed), "remote peer must not stop the server");
        let (status, _, _) = route(&state, &req(true));
        assert_eq!(status, 200);
        assert!(state.stop.load(Ordering::Relaxed));
    }

    #[test]
    fn variant_names_are_validated_and_labels_escaped() {
        assert!(valid_variant_name("gpt-nano@0.5"));
        assert!(valid_variant_name("dense_v1.2:a"));
        assert!(!valid_variant_name(""));
        assert!(!valid_variant_name("a\"} 1\nfake{x=\""));
        assert!(!valid_variant_name(&"x".repeat(65)));
        assert_eq!(label_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
