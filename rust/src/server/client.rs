//! Minimal blocking HTTP/1.1 client — just enough to drive the serving
//! endpoints from `repro bench-serve` and the integration tests.
//!
//! Two flavors: the one-shot [`request`]/[`get`]/[`post_json`] helpers
//! (`Connection: close`, read-to-EOF — fine for occasional calls), and the
//! persistent [`Conn`] which keeps one keep-alive socket open across
//! requests, reading `Content-Length`-bounded bodies.  Load generators and
//! pollers use `Conn` so they stop paying per-request TCP setup.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Generous ceiling: a `/generate` against a cold engine may sit behind a
/// pretraining run on first boot.
const READ_TIMEOUT: Duration = Duration::from_secs(600);

pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    let text = String::from_utf8_lossy(&raw);
    let Some((head, rest)) = text.split_once("\r\n\r\n") else {
        bail!("malformed response (no header terminator)");
    };
    let status_line = head.lines().next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {status_line:?}"))?;
    Ok((status, rest.to_string()))
}

pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    request(addr, "GET", path, None)
}

/// POST a JSON value and parse the JSON response body.
pub fn post_json(addr: SocketAddr, path: &str, body: &Json) -> Result<(u16, Json)> {
    let (status, text) = request(addr, "POST", path, Some(&body.to_string()))?;
    let parsed = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("non-json response ({status}): {e} — body {text:?}"))?;
    Ok((status, parsed))
}

/// A persistent keep-alive connection.  Lazily (re)connects: the first
/// request dials, later ones reuse the socket, and an IO failure mid-cycle
/// (server reaped an idle connection, process restarted) retries once on a
/// fresh socket before surfacing the error.
pub struct Conn {
    addr: SocketAddr,
    stream: Option<TcpStream>,
}

impl Conn {
    pub fn new(addr: SocketAddr) -> Conn {
        Conn { addr, stream: None }
    }

    fn connect(&mut self) -> Result<&mut TcpStream> {
        if self.stream.is_none() {
            let s = TcpStream::connect(self.addr)
                .with_context(|| format!("connecting {}", self.addr))?;
            let _ = s.set_read_timeout(Some(READ_TIMEOUT));
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().expect("just connected"))
    }

    /// One request/response cycle on the persistent socket.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        match self.try_cycle(method, path, body) {
            Ok(r) => Ok(r),
            Err(_) => {
                // stale socket (idle-reaped or the server bounced): one
                // fresh-connection retry, then give up honestly
                self.stream = None;
                self.try_cycle(method, path, body)
            }
        }
    }

    fn try_cycle(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
        let addr = self.addr;
        let stream = self.connect()?;
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        );
        let cycle = (|| -> Result<(u16, String)> {
            stream.write_all(head.as_bytes())?;
            stream.write_all(body.as_bytes())?;
            read_response(stream)
        })();
        if cycle.is_err() {
            self.stream = None; // never reuse a half-consumed socket
        }
        cycle
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None)
    }

    /// POST a JSON value and parse the JSON response body.
    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let (status, text) = self.request("POST", path, Some(&body.to_string()))?;
        let parsed = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("non-json response ({status}): {e} — body {text:?}"))?;
        Ok((status, parsed))
    }
}

/// Read one keep-alive response: headers, then exactly `Content-Length`
/// body bytes (the server always sends the header).
fn read_response(stream: &mut TcpStream) -> Result<(u16, String)> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        let n = stream.read(&mut tmp).context("reading response head")?;
        if n == 0 {
            bail!("connection closed mid-response");
        }
        buf.extend_from_slice(&tmp[..n]);
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let status_line = head.lines().next().unwrap_or("");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {status_line:?}"))?;
    let mut content_length = 0usize;
    for line in head.lines().skip(1) {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let body_start = header_end + 4;
    while buf.len() < body_start + content_length {
        let n = stream.read(&mut tmp).context("reading response body")?;
        if n == 0 {
            bail!("connection closed mid-body");
        }
        buf.extend_from_slice(&tmp[..n]);
    }
    let body =
        String::from_utf8_lossy(&buf[body_start..body_start + content_length]).into_owned();
    Ok((status, body))
}
