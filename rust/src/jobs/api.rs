//! Request/response shapes for the `/jobs` endpoints: parse a submit body
//! into a validated [`JobSpec`], and render [`JobRecord`]s as summary /
//! detail JSON.
//!
//! A submit body carries the graph either inline (`"plan"`: a plan-graph
//! JSON object, same schema as `repro run` files) or as a `"stages"`
//! string in the `--stages` grammar, plus optional knobs:
//!
//! ```json
//! {
//!   "stages": "prune(magnitude,0.5)|eval(ppl)",
//!   "name": "halfsparse",            // default: graph name
//!   "profile": "quick",              // re-resolve from a named profile
//!   "config": { "retrain_steps": 50 }, // field-level overrides
//!   "model": "gpt-nano",             // shorthand for config.model
//!   "layout": "csr",                 // shorthand for config.layout
//!   "seed": 0,
//!   "jobs": 2                        // executor workers for this graph
//! }
//! ```
//!
//! Validation (graph shape, config fields, cache-key derivation) happens
//! here, before anything is persisted — a bad submit is a 400, never a
//! failed job.

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::pipeline::{parse::parse_graph, PlanGraph};
use crate::util::json::Json;

use super::store::{JobRecord, JobSpec};

/// Parse + validate a `POST /jobs` body.  `base` is the daemon's resolved
/// config (its profile/model flags); `default_seed` its `--seed`.
pub fn parse_submit(j: &Json, base: &ExperimentConfig, default_seed: u64) -> Result<JobSpec> {
    let graph = match (j.get("plan"), j.get("stages")) {
        (Some(_), Some(_)) => bail!("submit body has both \"plan\" and \"stages\"; pick one"),
        (Some(p), None) => {
            PlanGraph::from_json(p).map_err(|e| anyhow::anyhow!("parsing \"plan\": {e}"))?
        }
        (None, Some(s)) => {
            let spec = s.as_str().context("\"stages\" must be a string")?;
            let name = j.str_or("name", "job");
            parse_graph(&name, spec).map_err(|e| anyhow::anyhow!("parsing \"stages\": {e}"))?
        }
        (None, None) => bail!("submit body needs a \"plan\" object or a \"stages\" string"),
    };
    let mut cfg = match j.get("profile").and_then(Json::as_str) {
        Some(p) => {
            let model = j.get("model").and_then(Json::as_str).unwrap_or(&base.model);
            ExperimentConfig::profile(p, model)?
        }
        None => base.clone(),
    };
    if let Some(c) = j.get("config") {
        cfg = cfg.with_json(c).context("applying \"config\" overrides")?;
    }
    if let Some(m) = j.get("model").and_then(Json::as_str) {
        cfg.model = m.to_string();
    }
    if let Some(l) = j.get("layout").and_then(Json::as_str) {
        cfg.layout = l.to_string();
    }
    cfg.validate()?;
    let seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(default_seed);
    let jobs = j.get("jobs").and_then(Json::as_usize).unwrap_or(1).max(1);
    graph.validate().map_err(|e| anyhow::anyhow!("invalid graph: {e}"))?;
    graph
        .node_keys(&cfg, seed)
        .map_err(|e| anyhow::anyhow!("keying graph: {e}"))?;
    let name = j.str_or("name", &graph.name);
    Ok(JobSpec { name, graph, cfg, seed, jobs })
}

/// One-line listing entry (`GET /jobs`).
pub fn job_summary(rec: &JobRecord) -> Json {
    Json::obj(vec![
        ("id", Json::Str(rec.id.clone())),
        ("name", Json::Str(rec.spec.name.clone())),
        ("status", Json::Str(rec.status.as_str().to_string())),
        ("nodes_done", Json::Num(rec.nodes_done() as f64)),
        ("nodes_total", Json::Num(rec.nodes.len() as f64)),
        ("attempts", Json::Num(rec.attempts as f64)),
        ("created_unix", Json::Num(rec.created_unix as f64)),
    ])
}

/// Full record (`GET /jobs/<id>`): the persisted `job.json` verbatim —
/// per-node status, warnings, aggregates, everything.
pub fn job_detail(rec: &JobRecord) -> Json {
    rec.to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExperimentConfig {
        ExperimentConfig::quick("gpt-nano")
    }

    #[test]
    fn submit_from_stages_string() {
        let j = Json::parse(
            r#"{"stages": "prune(magnitude,0.5)|eval(ppl)", "name": "half", "jobs": 3, "seed": 9}"#,
        )
        .unwrap();
        let spec = parse_submit(&j, &base(), 0).unwrap();
        assert_eq!(spec.name, "half");
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.jobs, 3);
        assert_eq!(spec.graph.stage_count(), 3, "pretrain prepended");
    }

    #[test]
    fn submit_from_inline_plan_object() {
        let g = parse_graph("inline", "prune(magnitude,0.7)|eval(ppl)").unwrap();
        let body = Json::obj(vec![("plan", g.to_json())]);
        let spec = parse_submit(&body, &base(), 5).unwrap();
        assert_eq!(spec.name, "inline");
        assert_eq!(spec.seed, 5, "daemon default seed");
        assert_eq!(spec.graph, g);
    }

    #[test]
    fn submit_applies_config_overrides() {
        let j = Json::parse(
            r#"{"stages": "prune(magnitude,0.5)|eval(ppl)",
                "config": {"retrain_steps": 11}, "layout": "csr"}"#,
        )
        .unwrap();
        let spec = parse_submit(&j, &base(), 0).unwrap();
        assert_eq!(spec.cfg.retrain_steps, 11);
        assert_eq!(spec.cfg.layout, "csr");
    }

    #[test]
    fn submit_rejects_garbage() {
        let no_graph = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(parse_submit(&no_graph, &base(), 0).is_err());
        let both = Json::parse(r#"{"stages": "eval", "plan": {"nodes": []}}"#).unwrap();
        assert!(parse_submit(&both, &base(), 0).is_err());
        let bad_stage = Json::parse(r#"{"stages": "explode(now)"}"#).unwrap();
        assert!(parse_submit(&bad_stage, &base(), 0).is_err());
        let bad_cfg = Json::parse(
            r#"{"stages": "prune(magnitude,0.5)|eval(ppl)", "layout": "coo"}"#,
        )
        .unwrap();
        assert!(parse_submit(&bad_cfg, &base(), 0).is_err());
    }
}
