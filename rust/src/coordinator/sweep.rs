//! Experiment registry: every paper table/figure as a sweep over the
//! pipeline verbs, emitting markdown tables (EXPERIMENTS.md records them).
//!
//! | exp id   | paper artifact       | shape reproduced                          |
//! |----------|----------------------|-------------------------------------------|
//! | fig1     | Fig 1/3/4            | ppl+acc vs sparsity per retrained subset  |
//! | table1   | Table 1/7/8          | subsets vs full FT across sparsities      |
//! | table2   | Table 2/9–14         | LoRA variants × {50%, 2:4, 4:8}           |
//! | fig2     | Fig 2                | MaskLoRA ppl vs retrain iterations        |
//! | table3   | Table 3/24           | per-task Δacc from MaskLoRA retraining    |
//! | table4   | Table 4              | retraining throughput (tps)               |
//! | table5   | Table 5/15–18        | recon on/off × pruner × pattern           |
//! | table19  | Table 19             | MaskLoRA vs full-FT reconstruction        |
//! | table20  | Tables 20/21         | subset-combination ablation               |
//! | table22  | Tables 22/23         | high-sparsity recon vs retrain            |
//! | memory   | §3.2 efficiency      | analytical 30B-on-one-A100 table          |
//!
//! Pretrained dense checkpoints are cached per (model, seed, steps) so every
//! sweep shares one convergence run.  `fig2` and `table22` go further: their
//! cells are *plan generators* ([`fig2_plan`], [`table22_plan`]) executed
//! through [`crate::pipeline::Executor`], so sweeps, `repro run` and the
//! shim subcommands share one execution path and one content-addressed
//! stage cache — re-running a sweep only computes cells whose plans changed.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::reconstruct::{self, ReconMode};
use crate::coordinator::Session;
use crate::peft::Mode;
use crate::pipeline::{Executor, Plan};
use crate::pruning::{Criterion, Pattern};
use crate::runtime::Backend;
use crate::tensor::Tensor;
use crate::util::bench::Table;

pub const EXPERIMENTS: [&str; 11] = [
    "fig1", "table1", "table2", "fig2", "table3", "table4", "table5",
    "table19", "table20", "table22", "memory",
];

pub struct ExpContext<'rt> {
    pub rt: &'rt dyn Backend,
    pub cfg: ExperimentConfig,
    pub cache_dir: PathBuf,
}

#[derive(Debug, Clone, Default)]
pub struct CellResult {
    pub ppl: f64,
    pub acc: f64,
    pub per_task: Vec<(String, f64)>,
    pub tps: f64,
    pub trainable_pct: f64,
}

impl<'rt> ExpContext<'rt> {
    pub fn new(rt: &'rt dyn Backend, cfg: ExperimentConfig, cache_dir: PathBuf) -> Self {
        ExpContext { rt, cfg, cache_dir }
    }

    /// A session holding converged dense weights (cached on disk).  The key
    /// covers everything pretraining reads — model, seed, steps, lr, data
    /// seed and backend — so a stale checkpoint can never satisfy a changed
    /// config (the plan executor relies on this).
    pub fn dense_session(&self, seed: u64) -> Result<Session<'rt>> {
        let mut s = Session::new(self.rt, self.cfg.clone(), seed)?;
        let key = format!(
            "{}-s{}-p{}-lr{}-d{}-{}.ptns",
            self.cfg.model,
            seed,
            self.cfg.pretrain_steps,
            self.cfg.pretrain_lr,
            self.cfg.data_seed,
            self.cfg.backend,
        );
        let path = self.cache_dir.join(key);
        if path.exists() {
            s.load(&path)?;
        } else {
            crate::info!(
                "pretraining {} for {} steps (cache miss)",
                self.cfg.model,
                self.cfg.pretrain_steps
            );
            s.pretrain(self.cfg.pretrain_steps, self.cfg.pretrain_lr)?;
            std::fs::create_dir_all(&self.cache_dir).ok();
            s.save(&path)?;
        }
        Ok(s)
    }

    /// Dense → calibrate (if needed) → prune.  Returns the session plus the
    /// dense weight snapshot (reconstruction targets).
    pub fn pruned_session(
        &self,
        seed: u64,
        criterion: Criterion,
        pattern: Pattern,
    ) -> Result<(Session<'rt>, BTreeMap<String, Tensor>)> {
        let mut s = self.dense_session(seed)?;
        let dense: BTreeMap<String, Tensor> = s
            .mm
            .prunable
            .iter()
            .map(|n| (n.clone(), s.params.get(n).clone()))
            .collect();
        let grams = if criterion.needs_calibration() {
            Some(s.calibrate()?)
        } else {
            None
        };
        s.prune(criterion, pattern, grams.as_ref())?;
        Ok((s, dense))
    }

    /// Retrain with the best LR from the grid (tuned on val ppl, like the
    /// paper).  Returns the best cell plus the chosen lr.
    pub fn retrain_tuned(
        &self,
        base: &Session<'rt>,
        mode: Mode,
        steps: u64,
        with_tasks: bool,
    ) -> Result<(CellResult, f64)> {
        let mut best: Option<(CellResult, f64)> = None;
        for &lr in &self.cfg.lr_grid {
            let mut s = self.clone_session(base)?;
            s.retrain(mode, steps, lr)?;
            if mode != Mode::Lora {
                // standard LoRA stays unmerged (Table 2's "Mergeable: no")
                s.merge_adapters()?;
            }
            let cell = self.evaluate(&mut s, with_tasks, Some(mode))?;
            if best.as_ref().map(|(b, _)| cell.ppl < b.ppl).unwrap_or(true) {
                best = Some((cell, lr));
            }
        }
        Ok(best.expect("non-empty lr grid"))
    }

    /// Clone a session's mutable state into a fresh session (shares nothing).
    pub fn clone_session(&self, base: &Session<'rt>) -> Result<Session<'rt>> {
        let mut s = Session::new(self.rt, self.cfg.clone(), 0)?;
        s.params = base.params.clone();
        s.masks = base.masks.clone();
        s.refresh_sparse();
        Ok(s)
    }

    pub fn evaluate(
        &self,
        s: &mut Session<'rt>,
        with_tasks: bool,
        mode: Option<Mode>,
    ) -> Result<CellResult> {
        let ppl = s.eval_ppl_test()?;
        let (acc, per_task) = if with_tasks {
            let tr = s.eval_tasks()?;
            (
                crate::eval::mean_accuracy(&tr),
                tr.into_iter().map(|t| (t.name, t.accuracy)).collect(),
            )
        } else {
            (f64::NAN, Vec::new())
        };
        let trainable_pct = mode
            .map(|m| {
                let key = m.trainable_key();
                100.0 * s.mm.trainable_count(key) as f64 / s.mm.total_params() as f64
            })
            .unwrap_or(0.0);
        Ok(CellResult {
            ppl: ppl.ppl,
            acc,
            per_task,
            tps: s.last_tps,
            trainable_pct,
        })
    }
}

fn fmt_ppl(p: f64) -> String {
    if p.is_nan() {
        "-".into()
    } else if p > 1000.0 {
        format!("{p:.0}")
    } else {
        format!("{p:.2}")
    }
}

fn fmt_acc(a: f64) -> String {
    if a.is_nan() {
        "-".into()
    } else {
        format!("{:.1}%", a * 100.0)
    }
}

/// Entry point: run one experiment id, return its tables.
pub fn run(ctx: &ExpContext, exp: &str) -> Result<Vec<Table>> {
    match exp {
        "fig1" => fig1(ctx),
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "fig2" => fig2(ctx),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "table5" => table5(ctx),
        "table19" => table19(ctx),
        "table20" => table20(ctx),
        "table22" => table22(ctx),
        "memory" => memory(ctx),
        other => bail!("unknown experiment {other:?}; known: {EXPERIMENTS:?}"),
    }
}

const SPARSITIES: [f64; 5] = [0.3, 0.4, 0.5, 0.6, 0.7];

/// Fig 1/3/4 + Table 1 share this engine: subsets (+ optionally MaskLoRA +
/// full FT) across sparsities, reporting ppl and accuracy.
fn subset_sweep(ctx: &ExpContext, modes: &[Option<Mode>], title: &str) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let dense = {
        let mut s = ctx.dense_session(seed)?;
        ctx.evaluate(&mut s, true, None)?
    };
    let mut headers = vec!["Method".to_string(), "% trainable".to_string()];
    headers.extend(SPARSITIES.iter().map(|s| format!("{:.0}%", s * 100.0)));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut ppl_t = Table::new(&format!("{title} — perplexity (dense {:.2})", dense.ppl), &hdr);
    let mut acc_t = Table::new(&format!("{title} — zero-shot acc (dense {})", fmt_acc(dense.acc)), &hdr);

    for mode in modes {
        let mut ppl_row = Vec::new();
        let mut acc_row = Vec::new();
        let mut pct = 0.0;
        for &sp in &SPARSITIES {
            let (base, _) = ctx.pruned_session(seed, Criterion::Magnitude, Pattern::Unstructured(sp))?;
            let cell = match mode {
                None => {
                    let mut s = ctx.clone_session(&base)?;
                    ctx.evaluate(&mut s, true, None)?
                }
                Some(m) => ctx.retrain_tuned(&base, *m, ctx.cfg.retrain_steps, true)?.0,
            };
            pct = cell.trainable_pct;
            ppl_row.push(fmt_ppl(cell.ppl));
            acc_row.push(fmt_acc(cell.acc));
        }
        let name = mode.map(|m| m.name().to_string()).unwrap_or("none".into());
        let mut r1 = vec![name.clone(), format!("{pct:.3}%")];
        r1.extend(ppl_row);
        ppl_t.row(r1);
        let mut r2 = vec![name, format!("{pct:.3}%")];
        r2.extend(acc_row);
        acc_t.row(r2);
    }
    Ok(vec![ppl_t, acc_t])
}

fn fig1(ctx: &ExpContext) -> Result<Vec<Table>> {
    subset_sweep(
        ctx,
        &[
            None,
            Some(Mode::Head),
            Some(Mode::Embed),
            Some(Mode::Biases),
            Some(Mode::Ln),
            Some(Mode::Full),
        ],
        "Fig 1/3/4: subset retraining vs sparsity (magnitude pruning)",
    )
}

fn table1(ctx: &ExpContext) -> Result<Vec<Table>> {
    let mut modes: Vec<Option<Mode>> = vec![
        Some(Mode::Full),
        Some(Mode::MaskLora),
        Some(Mode::Biases),
        Some(Mode::Ln),
        None,
    ];
    // LLaMA-style models have no biases (Table 8)
    if ctx.rt.model(&ctx.cfg.model)?.trainable_count("biases") == 0 {
        modes.retain(|m| *m != Some(Mode::Biases));
    }
    subset_sweep(ctx, &modes, "Table 1/7/8: PERP vs full retraining")
}

fn patterns_for_table2() -> Vec<Pattern> {
    vec![
        Pattern::Unstructured(0.5),
        Pattern::SemiStructured { n: 2, m: 4 },
        Pattern::SemiStructured { n: 4, m: 8 },
    ]
}

fn table2(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let hdr = ["Method", "Mergeable", "Sparsity", "Perplexity", "Accuracy"];
    let mut t = Table::new("Table 2/9-14: LoRA variants (magnitude pruning)", &hdr);
    {
        let mut s = ctx.dense_session(seed)?;
        let d = ctx.evaluate(&mut s, true, None)?;
        t.row(vec![
            "baseline".into(), "-".into(), "0%".into(), fmt_ppl(d.ppl), fmt_acc(d.acc),
        ]);
    }
    for pattern in patterns_for_table2() {
        for mode in Mode::ALL_LORA {
            let (base, _) = ctx.pruned_session(seed, Criterion::Magnitude, pattern)?;
            let (cell, _lr) = ctx.retrain_tuned(&base, mode, ctx.cfg.retrain_steps, true)?;
            let mergeable = match mode.mergeable_sparsity_preserving() {
                Some(true) => "yes",
                Some(false) => "no",
                None => "-",
            };
            t.row(vec![
                mode.name().into(),
                mergeable.into(),
                pattern.label(),
                fmt_ppl(cell.ppl),
                fmt_acc(cell.acc),
            ]);
        }
    }
    Ok(vec![t])
}

/// Plan generator for one Fig 2 cell: the sweep below and one-off
/// `repro run` invocations share the executor path (and therefore the
/// content-addressed stage cache — every cell at one sparsity reuses the
/// same pruned artifact).
pub fn fig2_plan(sparsity: f64, iters: u64, lr: f64) -> Plan {
    let p = Plan::new(&format!("fig2-sp{sparsity}-it{iters}"))
        .pretrain()
        .prune(Criterion::Magnitude, Pattern::Unstructured(sparsity));
    if iters == 0 {
        p.eval_ppl()
    } else {
        p.retrain(Mode::MaskLora, Some(iters), Some(lr)).merge().eval_ppl()
    }
}

fn fig2(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let ex = Executor::new(ctx.rt, ctx.cfg.clone(), ctx.cache_dir.clone(), seed).quiet(true);
    let iters: Vec<u64> = [0u64, 5, 15, 50, 150, 300]
        .into_iter()
        .filter(|&i| i <= ctx.cfg.retrain_steps.max(30) * 3)
        .collect();
    let mut headers = vec!["Sparsity".to_string()];
    headers.extend(iters.iter().map(|i| format!("it {i}")));
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig 2: MaskLoRA perplexity vs retraining iterations", &hdr);
    for sp in [0.4, 0.5, 0.6, 0.7] {
        let mut row = vec![format!("{:.0}%", sp * 100.0)];
        for &it in &iters {
            let rep = ex.run(&fig2_plan(sp, it, ctx.cfg.lr_grid[0]))?;
            row.push(fmt_ppl(rep.last_metrics().map(|m| m.ppl).unwrap_or(f64::NAN)));
        }
        t.row(row);
    }
    Ok(vec![t])
}

fn table3(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let mut headers = vec!["Method".to_string(), "Sparsity".to_string()];
    headers.extend(crate::data::tasks::TASK_NAMES.iter().map(|s| s.to_string()));
    headers.push("Average".to_string());
    let hdr: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "Table 3/24: Δ zero-shot accuracy from MaskLoRA retraining",
        &hdr,
    );
    for sp in [0.5, 0.6, 0.7] {
        for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt] {
            let (base, _) = ctx.pruned_session(seed, crit, Pattern::Unstructured(sp))?;
            let before = {
                let mut s = ctx.clone_session(&base)?;
                ctx.evaluate(&mut s, true, None)?
            };
            let (after, _) = ctx.retrain_tuned(&base, Mode::MaskLora, ctx.cfg.retrain_steps, true)?;
            let mut row = vec![crit.name().to_string(), format!("{:.0}%", sp * 100.0)];
            let b: BTreeMap<_, _> = before.per_task.iter().cloned().collect();
            let mut deltas = Vec::new();
            for (name, acc) in &after.per_task {
                let d = acc - b.get(name).copied().unwrap_or(0.0);
                deltas.push(d);
                row.push(format!("{:+.1}%", d * 100.0));
            }
            let avg = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
            row.push(format!("{:+.1}%", avg * 100.0));
            t.row(row);
        }
    }
    Ok(vec![t])
}

fn table4(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let hdr = ["Method", "% trainable", "tokens/s", "relative"];
    let mut t = Table::new("Table 4: retraining throughput", &hdr);
    let steps = ctx.cfg.retrain_steps.min(30).max(10);
    let (base, _) = ctx.pruned_session(seed, Criterion::Magnitude, Pattern::Unstructured(0.5))?;
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for mode in [
        Mode::Full,
        Mode::Lora,
        Mode::ScaleLora,
        Mode::MaskLoraStd,
        Mode::MaskLora,
        Mode::BiasesLn,
    ] {
        let mut s = ctx.clone_session(&base)?;
        // warmup pass: compiles the executable + faults in caches so the
        // measured pass is steady-state (paper reports steady-state tps)
        s.retrain(mode, 3, ctx.cfg.lr_grid[0])?;
        s.retrain(mode, steps, ctx.cfg.lr_grid[0])?;
        let pct = 100.0 * s.mm.trainable_count(mode.trainable_key()) as f64
            / s.mm.total_params() as f64;
        let label = match mode {
            Mode::MaskLora => "masklora (optimized)".to_string(),
            Mode::MaskLoraStd => "masklora (standard)".to_string(),
            m => m.name().to_string(),
        };
        rows.push((label, pct, s.last_tps));
    }
    let full_tps = rows[0].2;
    for (name, pct, tps) in rows {
        t.row(vec![
            name,
            format!("{pct:.3}%"),
            format!("{tps:.0}"),
            format!("{:.2}x", tps / full_tps),
        ]);
    }
    Ok(vec![t])
}

fn recon_sweep(
    ctx: &ExpContext,
    patterns: &[Pattern],
    criteria: &[Criterion],
    title: &str,
) -> Result<Table> {
    let seed = ctx.cfg.seeds[0];
    let hdr = ["Method", "Reconstruction", "Sparsity", "Perplexity", "Accuracy"];
    let mut t = Table::new(title, &hdr);
    {
        let mut s = ctx.dense_session(seed)?;
        let d = ctx.evaluate(&mut s, true, None)?;
        t.row(vec![
            "baseline".into(), "-".into(), "0%".into(), fmt_ppl(d.ppl), fmt_acc(d.acc),
        ]);
    }
    for &pattern in patterns {
        for &crit in criteria {
            let (base, dense) = ctx.pruned_session(seed, crit, pattern)?;
            // without reconstruction
            let cell0 = {
                let mut s = ctx.clone_session(&base)?;
                ctx.evaluate(&mut s, true, None)?
            };
            t.row(vec![
                crit.name().into(), "no".into(), pattern.label(),
                fmt_ppl(cell0.ppl), fmt_acc(cell0.acc),
            ]);
            // with MaskLoRA reconstruction.  SparseGPT's own update IS its
            // reconstruction starting point, so targets stay the original
            // dense weights while the walk starts from the pruned state.
            let mut s = ctx.clone_session(&base)?;
            let target = s.masks.clone();
            reconstruct::reconstruct(
                &mut s, &target, &dense, ReconMode::MaskLora,
                ctx.cfg.recon_steps, ctx.cfg.recon_lr,
            )?;
            let cell1 = ctx.evaluate(&mut s, true, None)?;
            t.row(vec![
                crit.name().into(), "yes".into(), pattern.label(),
                fmt_ppl(cell1.ppl), fmt_acc(cell1.acc),
            ]);
        }
    }
    Ok(t)
}

fn table5(ctx: &ExpContext) -> Result<Vec<Table>> {
    let t = recon_sweep(
        ctx,
        &patterns_for_table2(),
        &[Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt],
        "Table 5/15-18: layer-wise reconstruction",
    )?;
    Ok(vec![t])
}

fn table19(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let hdr = ["Method", "40%", "50%", "60%", "70%"];
    let mut t = Table::new(
        "Table 19: MaskLoRA vs Full-FT reconstruction (zero-shot acc)",
        &hdr,
    );
    let mut rows: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    for sp in [0.4, 0.5, 0.6, 0.7] {
        let (base, dense) =
            ctx.pruned_session(seed, Criterion::Magnitude, Pattern::Unstructured(sp))?;
        for (label, mode) in [("full_ft", ReconMode::FullFt), ("masklora", ReconMode::MaskLora)] {
            let mut s = ctx.clone_session(&base)?;
            let target = s.masks.clone();
            reconstruct::reconstruct(
                &mut s, &target, &dense, mode, ctx.cfg.recon_steps, ctx.cfg.recon_lr,
            )?;
            let cell = ctx.evaluate(&mut s, true, None)?;
            rows.entry(label).or_default().push(fmt_acc(cell.acc));
        }
    }
    for (label, cells) in rows {
        let mut row = vec![label.to_string()];
        row.extend(cells);
        t.row(row);
    }
    Ok(vec![t])
}

fn table20(ctx: &ExpContext) -> Result<Vec<Table>> {
    // subset-combination ablation over the modes we lower; the full 32-combo
    // grid needs the --ablation artifact set (combo_* executables).
    let seed = ctx.cfg.seeds[0];
    let mm = ctx.rt.model(&ctx.cfg.model)?;
    let mut combos: Vec<(String, Option<Mode>)> = vec![
        ("none".into(), None),
        ("biases".into(), Some(Mode::Biases)),
        ("ln".into(), Some(Mode::Ln)),
        ("head".into(), Some(Mode::Head)),
        ("embed".into(), Some(Mode::Embed)),
        ("biases+ln".into(), Some(Mode::BiasesLn)),
        ("masklora(+biases+ln)".into(), Some(Mode::MaskLora)),
    ];
    // combo executables present? (aot --ablation)
    let combo_modes: Vec<String> = mm
        .executables
        .keys()
        .filter_map(|k| k.strip_prefix("train_combo_").map(|s| s.to_string()))
        .collect();
    for c in &combo_modes {
        combos.push((c.replace('_', "+"), None)); // handled specially below
    }

    let mut tables = Vec::new();
    for sp in [0.5, 0.7] {
        let hdr = ["Combination", "% trainable", "Perplexity"];
        let mut t = Table::new(
            &format!("Table 20/21: parameter-group ablation at {:.0}%", sp * 100.0),
            &hdr,
        );
        let (base, _) = ctx.pruned_session(seed, Criterion::Magnitude, Pattern::Unstructured(sp))?;
        for (label, mode) in &combos {
            let (ppl, pct) = match (label.as_str(), mode) {
                ("none", None) => {
                    let mut s = ctx.clone_session(&base)?;
                    (ctx.evaluate(&mut s, false, None)?.ppl, 0.0)
                }
                (_, Some(m)) => {
                    let (cell, _) = ctx.retrain_tuned(&base, *m, ctx.cfg.retrain_steps, false)?;
                    (cell.ppl, cell.trainable_pct)
                }
                (combo, None) => {
                    // generic combo executable path
                    let mode_key = format!("combo_{}", combo.replace('+', "_"));
                    let mut s = ctx.clone_session(&base)?;
                    s.retrain_custom(&mode_key, ctx.cfg.retrain_steps, ctx.cfg.lr_grid[0])?;
                    let cell = ctx.evaluate(&mut s, false, None)?;
                    let pct = 100.0 * s.mm.trainable_count(&mode_key) as f64
                        / s.mm.total_params() as f64;
                    (cell.ppl, pct)
                }
            };
            t.row(vec![label.clone(), format!("{pct:.3}%"), fmt_ppl(ppl)]);
        }
        tables.push(t);
    }
    Ok(tables)
}

/// Plan generator for one Tables 22/23 cell (strategy × criterion ×
/// sparsity).  The three strategies at one (criterion, sparsity) share the
/// same `pretrain|prune` prefix, so they reuse one pruned artifact.
pub fn table22_plan(strategy: &str, crit: Criterion, sparsity: f64) -> Plan {
    let base = Plan::new(&format!("table22-{strategy}-{}-{sparsity}", crit.name()))
        .pretrain()
        .prune(crit, Pattern::Unstructured(sparsity));
    match strategy {
        "none" => base.eval_ppl(),
        "reconstruct" => base.reconstruct(ReconMode::MaskLora, None, None).eval_ppl(),
        "retrain" => base.retrain(Mode::MaskLora, None, None).merge().eval_ppl(),
        other => panic!("unknown table22 strategy {other:?} (none|reconstruct|retrain)"),
    }
}

fn table22(ctx: &ExpContext) -> Result<Vec<Table>> {
    let seed = ctx.cfg.seeds[0];
    let ex = Executor::new(ctx.rt, ctx.cfg.clone(), ctx.cache_dir.clone(), seed).quiet(true);
    let hdr = ["Method", "Strategy", "50%", "60%", "70%", "80%"];
    let mut t = Table::new(
        "Tables 22/23: high-sparsity regime — reconstruction vs retraining (ppl)",
        &hdr,
    );
    for crit in [Criterion::Magnitude, Criterion::Wanda, Criterion::SparseGpt] {
        for strategy in ["none", "reconstruct", "retrain"] {
            let mut row = vec![crit.name().to_string(), strategy.to_string()];
            for sp in [0.5, 0.6, 0.7, 0.8] {
                let rep = ex.run(&table22_plan(strategy, crit, sp))?;
                row.push(fmt_ppl(rep.last_metrics().map(|m| m.ppl).unwrap_or(f64::NAN)));
            }
            t.row(row);
        }
    }
    Ok(vec![t])
}

fn memory(_ctx: &ExpContext) -> Result<Vec<Table>> {
    let hdr = ["Method", "GiB (30B model)", "fits one A100-80G"];
    let mut t = Table::new("Memory model: the paper's 30B-on-one-GPU claim", &hdr);
    for (name, gib, fits) in crate::metrics::opt30b_fits_table() {
        t.row(vec![name, format!("{gib:.0}"), if fits { "yes" } else { "NO" }.into()]);
    }
    Ok(vec![t])
}

// re-export for main.rs
pub use crate::util::bench::Table as SweepTable;
