//! [`Stage`] / [`Plan`]: the typed pipeline vocabulary.
//!
//! A plan is an ordered list of stages over one model + experiment config.
//! Stages deliberately mirror the [`crate::coordinator::Session`] verbs —
//! the executor adds nothing semantically, it only sequences, caches and
//! reports.  Optional knobs (`steps`, `lr`) default to the experiment
//! config at execution time, so the same plan file runs under `--profile
//! quick` and `--profile full` unchanged.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::reconstruct::ReconMode;
use crate::peft::Mode;
use crate::pruning::{Criterion, Pattern};
use crate::util::json::Json;

/// One pipeline step.  All variants are value types: a stage is fully
/// described by its JSON object, which is also its cache-key contribution.
#[derive(Debug, Clone, PartialEq)]
pub enum Stage {
    /// Converge (or load the cached) dense model.  Must come first.
    Pretrain,
    /// Prune the current weights; `pattern` carries the sparsity.
    Prune { criterion: Criterion, pattern: Pattern },
    /// PERP retraining.  `steps` defaults to the config's `retrain_steps`;
    /// an unpinned `lr` is tuned over `lr_grid` on test perplexity like the
    /// paper (single-entry grids resolve straight to `lr_grid[0]`).
    Retrain { mode: Mode, steps: Option<u64>, lr: Option<f64> },
    /// Layer-wise Eq. 1 reconstruction toward the current masks; targets are
    /// the weights captured just before the preceding prune.
    Reconstruct { mode: ReconMode, steps: Option<u64>, lr: Option<f64> },
    /// Fold pending LoRA adapters back into the weights.
    Merge,
    /// Test perplexity (+ the zero-shot suite when `tasks`).
    Eval { tasks: bool },
    /// Save the current weights as a `.ptns` checkpoint.  Idempotent: when
    /// the target file already holds the exact bytes this node last wrote
    /// (recorded as a content fingerprint), the write is skipped and
    /// reported as a cache hit.
    Export { path: String },
}

impl Stage {
    /// Short human label for progress lines and tables.
    pub fn label(&self) -> String {
        match self {
            Stage::Pretrain => "pretrain".to_string(),
            Stage::Prune { criterion, pattern } => {
                format!("prune({},{})", criterion.name(), pattern.label())
            }
            Stage::Retrain { mode, steps, lr } => {
                let mut s = format!("retrain({}", mode.name());
                if let Some(n) = steps {
                    s.push_str(&format!(",{n}"));
                }
                if let Some(l) = lr {
                    s.push_str(&format!(",{l}"));
                }
                s.push(')');
                s
            }
            Stage::Reconstruct { mode, steps, lr } => {
                let mut s = format!("reconstruct({}", recon_mode_name(*mode));
                if let Some(n) = steps {
                    s.push_str(&format!(",{n}"));
                }
                if let Some(l) = lr {
                    s.push_str(&format!(",{l}"));
                }
                s.push(')');
                s
            }
            Stage::Merge => "merge".to_string(),
            Stage::Eval { tasks: true } => "eval".to_string(),
            Stage::Eval { tasks: false } => "eval(ppl)".to_string(),
            Stage::Export { path } => format!("export({path})"),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Stage::Pretrain => Json::obj(vec![("stage", Json::Str("pretrain".into()))]),
            Stage::Prune { criterion, pattern } => Json::obj(vec![
                ("stage", Json::Str("prune".into())),
                ("criterion", Json::Str(criterion.name().into())),
                ("sparsity", pattern_to_json(pattern)),
            ]),
            Stage::Retrain { mode, steps, lr } => {
                let mut pairs = vec![
                    ("stage", Json::Str("retrain".into())),
                    ("mode", Json::Str(mode.name().into())),
                ];
                if let Some(n) = steps {
                    pairs.push(("steps", Json::Num(*n as f64)));
                }
                if let Some(l) = lr {
                    pairs.push(("lr", Json::Num(*l)));
                }
                Json::obj(pairs)
            }
            Stage::Reconstruct { mode, steps, lr } => {
                let mut pairs = vec![
                    ("stage", Json::Str("reconstruct".into())),
                    ("mode", Json::Str(recon_mode_name(*mode).into())),
                ];
                if let Some(n) = steps {
                    pairs.push(("steps", Json::Num(*n as f64)));
                }
                if let Some(l) = lr {
                    pairs.push(("lr", Json::Num(*l)));
                }
                Json::obj(pairs)
            }
            Stage::Merge => Json::obj(vec![("stage", Json::Str("merge".into()))]),
            Stage::Eval { tasks } => Json::obj(vec![
                ("stage", Json::Str("eval".into())),
                ("tasks", Json::Bool(*tasks)),
            ]),
            Stage::Export { path } => Json::obj(vec![
                ("stage", Json::Str("export".into())),
                ("path", Json::Str(path.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Stage, String> {
        let kind = j
            .get("stage")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("stage object missing \"stage\" field: {j}"))?;
        match kind {
            "pretrain" => Ok(Stage::Pretrain),
            "prune" => {
                let criterion = Criterion::parse(
                    j.get("criterion").and_then(Json::as_str).unwrap_or("magnitude"),
                )?;
                let pattern = match j.get("sparsity") {
                    None => Pattern::Unstructured(0.5),
                    Some(v) => pattern_from_json(v)?,
                };
                Ok(Stage::Prune { criterion, pattern })
            }
            "retrain" => {
                let mode = Mode::parse(
                    j.get("mode")
                        .and_then(Json::as_str)
                        .ok_or_else(|| "retrain stage needs \"mode\"".to_string())?,
                )?;
                Ok(Stage::Retrain {
                    mode,
                    steps: opt_steps(j)?,
                    lr: j.get("lr").and_then(Json::as_f64),
                })
            }
            "reconstruct" => {
                let mode = recon_mode_parse(
                    j.get("mode").and_then(Json::as_str).unwrap_or("masklora"),
                )?;
                Ok(Stage::Reconstruct {
                    mode,
                    steps: opt_steps(j)?,
                    lr: j.get("lr").and_then(Json::as_f64),
                })
            }
            "merge" => Ok(Stage::Merge),
            "eval" => Ok(Stage::Eval {
                tasks: j.get("tasks").and_then(Json::as_bool).unwrap_or(true),
            }),
            "export" => Ok(Stage::Export {
                path: j
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| "export stage needs \"path\"".to_string())?
                    .to_string(),
            }),
            other => Err(format!("unknown stage kind {other:?}")),
        }
    }

    /// Canonical serialized form — the cache-key contribution of this stage
    /// (object keys are sorted by construction, so the form is stable).
    pub fn canonical(&self) -> String {
        self.to_json().to_string()
    }
}

/// Optional `"steps"` field: must be a non-negative integer when present
/// (the `as u64` cast would otherwise silently saturate/truncate, accepting
/// plans the inline grammar rejects).
fn opt_steps(j: &Json) -> Result<Option<u64>, String> {
    match j.get("steps") {
        None => Ok(None),
        Some(v) => {
            let f = v
                .as_f64()
                .ok_or_else(|| format!("\"steps\" must be a number, got {v}"))?;
            if f < 0.0 || f.fract() != 0.0 || f > u64::MAX as f64 {
                return Err(format!("\"steps\" must be a non-negative integer, got {v}"));
            }
            Ok(Some(f as u64))
        }
    }
}

fn pattern_to_json(p: &Pattern) -> Json {
    match p {
        Pattern::Unstructured(f) => Json::Num(*f),
        Pattern::SemiStructured { n, m } => Json::Str(format!("{n}:{m}")),
    }
}

fn pattern_from_json(j: &Json) -> Result<Pattern, String> {
    match j {
        Json::Num(f) => {
            // accept 0.5 and 50 (percent), like the CLI
            let f = if *f > 1.0 { *f / 100.0 } else { *f };
            Ok(Pattern::Unstructured(f))
        }
        Json::Str(s) => Pattern::parse(s),
        other => Err(format!("bad sparsity value {other}")),
    }
}

pub(crate) fn recon_mode_name(m: ReconMode) -> &'static str {
    match m {
        ReconMode::MaskLora => "masklora",
        ReconMode::FullFt => "full",
    }
}

pub(crate) fn recon_mode_parse(s: &str) -> Result<ReconMode, String> {
    match s {
        "masklora" => Ok(ReconMode::MaskLora),
        "full" | "full_ft" => Ok(ReconMode::FullFt),
        other => Err(format!("unknown reconstruction mode {other:?} (masklora|full)")),
    }
}

/// An ordered stage list plus a name (used in logs and reports only — the
/// cache key depends on the stages, never on the name).
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub name: String,
    pub stages: Vec<Stage>,
}

impl Plan {
    pub fn new(name: &str) -> Plan {
        Plan { name: name.to_string(), stages: Vec::new() }
    }

    // ----- builder --------------------------------------------------------

    pub fn stage(mut self, s: Stage) -> Plan {
        self.stages.push(s);
        self
    }
    pub fn pretrain(self) -> Plan {
        self.stage(Stage::Pretrain)
    }
    pub fn prune(self, criterion: Criterion, pattern: Pattern) -> Plan {
        self.stage(Stage::Prune { criterion, pattern })
    }
    /// Retrain with config-default steps/lr (pass `Some(..)` to pin them).
    pub fn retrain(self, mode: Mode, steps: Option<u64>, lr: Option<f64>) -> Plan {
        self.stage(Stage::Retrain { mode, steps, lr })
    }
    pub fn reconstruct(self, mode: ReconMode, steps: Option<u64>, lr: Option<f64>) -> Plan {
        self.stage(Stage::Reconstruct { mode, steps, lr })
    }
    pub fn merge(self) -> Plan {
        self.stage(Stage::Merge)
    }
    /// Perplexity + the zero-shot task suite.
    pub fn eval(self) -> Plan {
        self.stage(Stage::Eval { tasks: true })
    }
    /// Perplexity only.
    pub fn eval_ppl(self) -> Plan {
        self.stage(Stage::Eval { tasks: false })
    }
    pub fn export(self, path: &str) -> Plan {
        self.stage(Stage::Export { path: path.to_string() })
    }

    // ----- (de)serialization ----------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("stages", Json::Arr(self.stages.iter().map(Stage::to_json).collect())),
        ])
    }

    pub fn to_string_pretty(&self) -> String {
        // one stage per line keeps plan files diffable
        let mut out = String::new();
        out.push_str(&format!("{{\"name\":{},\n \"stages\":[\n", Json::Str(self.name.clone())));
        for (i, s) in self.stages.iter().enumerate() {
            out.push_str("  ");
            out.push_str(&s.to_json().to_string());
            if i + 1 < self.stages.len() {
                out.push(',');
            }
            out.push('\n');
        }
        out.push_str("]}\n");
        out
    }

    pub fn from_json(j: &Json) -> Result<Plan, String> {
        let name = j.str_or("name", "plan");
        let stages = j
            .get("stages")
            .and_then(Json::as_arr)
            .ok_or_else(|| "plan needs a \"stages\" array".to_string())?
            .iter()
            .map(Stage::from_json)
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Plan { name, stages })
    }

    pub fn from_text(s: &str) -> Result<Plan, String> {
        let j = Json::parse(s).map_err(|e| e.to_string())?;
        Plan::from_json(&j)
    }

    pub fn from_file(path: &Path) -> Result<Plan> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading plan {path:?}"))?;
        Plan::from_text(&text).map_err(|e| anyhow::anyhow!("parsing plan {path:?}: {e}"))
    }

    // ----- validation -----------------------------------------------------

    /// Structural validation: stage order must make sense before anything
    /// runs.  Tracks three facts — dense weights exist (pretrain), masks
    /// exist (prune/reconstruct), and whether a LoRA retrain is pending an
    /// explicit merge.
    pub fn validate(&self) -> Result<(), String> {
        if self.stages.is_empty() {
            return Err("plan has no stages".to_string());
        }
        let mut has_masks = false;
        let mut pending_lora: Option<Mode> = None;
        for (i, stage) in self.stages.iter().enumerate() {
            let at = |msg: &str| Err(format!("stage {} ({}): {msg}", i + 1, stage.label()));
            match stage {
                Stage::Pretrain => {
                    if i != 0 {
                        return at("pretrain must be the first stage");
                    }
                }
                _ if i == 0 => {
                    return at("plans must start with a pretrain stage");
                }
                Stage::Prune { .. } => {
                    if pending_lora.is_some() {
                        return at("merge the pending LoRA retrain before pruning again");
                    }
                    has_masks = true;
                }
                Stage::Retrain { .. } | Stage::Reconstruct { .. } => {
                    if !has_masks {
                        return at("requires masks — add a prune stage first");
                    }
                    if pending_lora.is_some() {
                        return at("merge the pending LoRA retrain first");
                    }
                    if let Stage::Retrain { mode, .. } = stage {
                        if mode.is_lora() {
                            pending_lora = Some(*mode);
                        }
                    }
                }
                Stage::Merge => {
                    if pending_lora.take().is_none() {
                        return at("merge requires a preceding LoRA-mode retrain");
                    }
                }
                Stage::Eval { .. } => {
                    // standard LoRA is the one variant evaluated unmerged
                    if matches!(pending_lora, Some(m) if m != Mode::Lora) {
                        return at("merge the pending LoRA retrain before evaluating");
                    }
                }
                Stage::Export { .. } => {
                    if pending_lora.is_some() {
                        return at("merge before export (adapters are not saved)");
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_plan() -> Plan {
        Plan::new("demo")
            .pretrain()
            .prune(Criterion::Wanda, Pattern::Unstructured(0.5))
            .retrain(Mode::MaskLora, Some(100), Some(1e-3))
            .merge()
            .eval()
            .export("results/demo.ptns")
    }

    #[test]
    fn builder_then_json_roundtrip() {
        let p = demo_plan();
        let text = p.to_json().to_string();
        let p2 = Plan::from_text(&text).unwrap();
        assert_eq!(p, p2);
        // the pretty form parses to the same plan
        let p3 = Plan::from_text(&p.to_string_pretty()).unwrap();
        assert_eq!(p, p3);
    }

    #[test]
    fn optional_fields_stay_optional() {
        let p = Plan::new("d")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::SemiStructured { n: 2, m: 4 })
            .retrain(Mode::Biases, None, None)
            .eval_ppl();
        let p2 = Plan::from_text(&p.to_json().to_string()).unwrap();
        assert_eq!(p, p2);
        match &p2.stages[2] {
            Stage::Retrain { steps, lr, .. } => {
                assert!(steps.is_none());
                assert!(lr.is_none());
            }
            other => panic!("wrong stage {other:?}"),
        }
    }

    #[test]
    fn sparsity_accepts_percent_and_nm() {
        let j = Json::parse(r#"{"stage":"prune","criterion":"wanda","sparsity":50}"#).unwrap();
        assert_eq!(
            Stage::from_json(&j).unwrap(),
            Stage::Prune { criterion: Criterion::Wanda, pattern: Pattern::Unstructured(0.5) }
        );
        let j = Json::parse(r#"{"stage":"prune","sparsity":"4:8"}"#).unwrap();
        assert_eq!(
            Stage::from_json(&j).unwrap(),
            Stage::Prune {
                criterion: Criterion::Magnitude,
                pattern: Pattern::SemiStructured { n: 4, m: 8 }
            }
        );
    }

    #[test]
    fn bad_steps_rejected_not_coerced() {
        for steps in ["-1", "2.5", "1e99", "\"many\""] {
            let text = format!(r#"{{"stage":"retrain","mode":"masklora","steps":{steps}}}"#);
            let j = Json::parse(&text).unwrap();
            let e = Stage::from_json(&j).unwrap_err();
            assert!(e.contains("steps"), "{steps}: {e}");
        }
        let j = Json::parse(r#"{"stage":"reconstruct","steps":-3}"#).unwrap();
        assert!(Stage::from_json(&j).is_err());
    }

    #[test]
    fn validation_accepts_good_plans() {
        demo_plan().validate().unwrap();
        // iterative prune→retrain cycle
        Plan::new("iter")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.3))
            .retrain(Mode::MaskLora, None, None)
            .merge()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::MaskLora, None, None)
            .merge()
            .eval()
            .validate()
            .unwrap();
        // standard LoRA may evaluate unmerged
        Plan::new("lora")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::Lora, None, None)
            .eval()
            .validate()
            .unwrap();
    }

    #[test]
    fn validation_rejects_bad_plans() {
        // merge without a lora retrain
        let e = Plan::new("x").pretrain().merge().validate().unwrap_err();
        assert!(e.contains("merge requires"), "{e}");
        // retrain without masks
        let e = Plan::new("x")
            .pretrain()
            .retrain(Mode::MaskLora, None, None)
            .validate()
            .unwrap_err();
        assert!(e.contains("masks"), "{e}");
        // pretrain not first
        let e = Plan::new("x")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .pretrain()
            .validate()
            .unwrap_err();
        assert!(e.contains("first"), "{e}");
        // eval with a pending (non-standard) lora retrain
        let e = Plan::new("x")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::MaskLora, None, None)
            .eval()
            .validate()
            .unwrap_err();
        assert!(e.contains("merge"), "{e}");
        // subset merge is meaningless
        let e = Plan::new("x")
            .pretrain()
            .prune(Criterion::Magnitude, Pattern::Unstructured(0.5))
            .retrain(Mode::Biases, None, None)
            .merge()
            .validate()
            .unwrap_err();
        assert!(e.contains("merge requires"), "{e}");
        // empty
        assert!(Plan::new("x").validate().is_err());
    }
}
