//! Batching: pack tokenized documents into fixed (batch, seq_len) blocks.
//!
//! Documents are concatenated with `<sep>` into a single stream per split
//! (GPT-style packing), then chunked.  Training batches are sampled with a
//! seeded RNG (infinite, shuffled-with-replacement over chunk windows);
//! eval batches walk the stream deterministically.  A calibration sampler
//! draws the fixed `n` sequences Wanda/SparseGPT/reconstruction share.

use crate::util::rng::Rng;

use super::tokenizer::{Tokenizer, BOS, SEP};

#[derive(Debug, Clone)]
pub struct Batcher {
    stream: Vec<i32>,
    pub seq_len: usize,
}

impl Batcher {
    pub fn new(docs_text: &[String], tok: &Tokenizer, seq_len: usize) -> Batcher {
        let mut stream = vec![BOS];
        for d in docs_text {
            stream.extend(tok.encode(d));
            stream.push(SEP);
        }
        Batcher { stream, seq_len }
    }

    pub fn from_ids(mut stream: Vec<i32>, seq_len: usize) -> Batcher {
        if stream.is_empty() {
            stream.push(BOS);
        }
        Batcher { stream, seq_len }
    }

    pub fn n_tokens(&self) -> usize {
        self.stream.len()
    }

    /// Number of non-overlapping eval windows.
    pub fn n_windows(&self) -> usize {
        self.stream.len() / self.seq_len
    }

    fn window(&self, i: usize) -> &[i32] {
        &self.stream[i * self.seq_len..(i + 1) * self.seq_len]
    }

    /// Deterministic eval batch `idx` of size `batch` (wraps around).
    pub fn eval_batch(&self, batch: usize, idx: usize) -> Vec<i32> {
        let n = self.n_windows().max(1);
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for b in 0..batch {
            let w = (idx * batch + b) % n;
            out.extend_from_slice(self.window(w));
        }
        out
    }

    /// Number of eval batches covering every window once.
    pub fn n_eval_batches(&self, batch: usize) -> usize {
        self.n_windows().div_ceil(batch).max(1)
    }

    /// Random train batch: `batch` windows at random offsets (not only
    /// window-aligned, to decorrelate epochs).
    pub fn train_batch(&self, batch: usize, rng: &mut Rng) -> Vec<i32> {
        let max_start = self.stream.len().saturating_sub(self.seq_len + 1).max(1);
        let mut out = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let start = rng.below(max_start as u64) as usize;
            out.extend_from_slice(&self.stream[start..start + self.seq_len]);
        }
        out
    }

    /// The shared calibration set: `n` deterministic windows from a seeded
    /// shuffle (paper: "we use the same set for both methods as well as the
    /// subsequent reconstruction").
    pub fn calibration(&self, n: usize, batch: usize, seed: u64) -> Vec<Vec<i32>> {
        let mut rng = Rng::new(seed ^ 0xCA11B);
        let mut windows: Vec<usize> = (0..self.n_windows().max(1)).collect();
        rng.shuffle(&mut windows);
        let mut batches = Vec::new();
        let mut taken = 0;
        while taken < n {
            let mut out = Vec::with_capacity(batch * self.seq_len);
            for b in 0..batch {
                let w = windows[(taken + b) % windows.len()];
                out.extend_from_slice(self.window(w.min(self.n_windows().saturating_sub(1))));
            }
            taken += batch;
            batches.push(out);
        }
        batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        let ids: Vec<i32> = (0..1000).map(|i| (i % 50) + 4).collect();
        Batcher::from_ids(ids, 32)
    }

    #[test]
    fn eval_batches_cover_stream() {
        let b = batcher();
        assert_eq!(b.n_windows(), 31);
        let n = b.n_eval_batches(4);
        assert_eq!(n, 8);
        let batch = b.eval_batch(4, 0);
        assert_eq!(batch.len(), 4 * 32);
        assert_eq!(batch[0], 4); // first token of stream
    }

    #[test]
    fn eval_batches_deterministic() {
        let b = batcher();
        assert_eq!(b.eval_batch(4, 3), b.eval_batch(4, 3));
    }

    #[test]
    fn train_batches_seeded() {
        let b = batcher();
        let mut r1 = Rng::new(5);
        let mut r2 = Rng::new(5);
        assert_eq!(b.train_batch(2, &mut r1), b.train_batch(2, &mut r2));
        let mut r3 = Rng::new(6);
        assert_ne!(b.train_batch(2, &mut r1), b.train_batch(2, &mut r3));
    }

    #[test]
    fn calibration_is_shared_and_sized() {
        let b = batcher();
        let c1 = b.calibration(16, 4, 99);
        let c2 = b.calibration(16, 4, 99);
        assert_eq!(c1, c2);
        assert_eq!(c1.len(), 4); // 16 seqs / batch 4
        assert_ne!(c1, b.calibration(16, 4, 100));
    }

    #[test]
    fn short_stream_still_works() {
        let b = Batcher::from_ids((0..40).collect(), 32);
        assert_eq!(b.n_windows(), 1);
        let batch = b.eval_batch(4, 0);
        assert_eq!(batch.len(), 128);
    }
}
