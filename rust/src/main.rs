//! `repro` — the PERP launcher.
//!
//! ```text
//! repro info                                      # models, executables, memory table
//! repro run --plan examples/plans/prune_retrain.json
//! repro run --stages "prune(wanda,0.5)|retrain(masklora,100)|merge|eval"
//! repro pretrain  --model gpt-nano --steps 200    # converge + cache dense weights
//! repro prune     --model gpt-nano --criterion wanda --sparsity 0.5
//! repro retrain   --model gpt-nano --mode masklora --steps 100
//! repro reconstruct --model gpt-nano --criterion magnitude --sparsity 0.5
//! repro eval      --model gpt-nano [--from pruned.ptns]
//! repro serve     --model gpt-nano [--from pruned.ptns] [--port 7777]
//! repro daemon    --model gpt-nano [--port 7766]  # durable job queue + HTTP API
//! repro jobs      submit --stages "prune(wanda,0.5)|eval" [--watch]
//! repro bench-serve --model gpt-nano              # batched vs sequential decode
//! repro sweep     --exp table1 [--model gpt-small] [--profile quick|full]
//! repro tables    [--profile quick]               # regenerate everything
//! ```
//!
//! Everything executes through `perp::pipeline`: `run` takes arbitrary plan
//! files or inline stage specs, and the classic subcommands are thin shims
//! that build small plans — so one-off runs, sweeps and plan files all share
//! the same content-addressed stage cache under `--out` (default
//! `results/`): re-running any plan (or its prefix) loads completed stages
//! instead of recomputing them.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use perp::config::ExperimentConfig;
use perp::coordinator::reconstruct::ReconMode;
use perp::coordinator::sweep::{self, ExpContext};
use perp::coordinator::Session;
use perp::jobs::{JobManager, JobRunner, JobStore};
use perp::peft::Mode;
use perp::pipeline::executor::{recorded_profile, stage_complete, stage_dir};
use perp::pipeline::parse::{parse_graph, parse_plan, spec_is_graph};
use perp::pipeline::{Executor, Plan, PlanOrGraph};
use perp::pruning::{Criterion, Pattern};
use perp::runtime::{default_artifacts_dir, open_backend, Backend, BackendKind};
use perp::server::{batcher, client, BatchCfg, EngineSpec, ServeState, Server};
use perp::util::cli::{ArgError, Args};
use perp::util::json::Json;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let result = dispatch(&args);
    // one process, one trace: flush whatever the command recorded (no-op
    // unless --trace/PERP_TRACE enabled tracing), even when it failed
    match perp::obs::trace::flush(None) {
        Ok(Some((path, spans))) => eprintln!("trace: {spans} spans -> {}", path.display()),
        Ok(None) => {}
        Err(e) => eprintln!("trace flush failed: {e}"),
    }
    if let Err(e) = result {
        // argument problems (bad values, unknown flags) exit 2, runtime
        // failures exit 1
        if let Some(ae) = e.downcast_ref::<ArgError>() {
            eprintln!("argument error: {ae}");
            std::process::exit(2);
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        "info" => info(args),
        "run" => run_cmd(args),
        "profile" => profile_cmd(args),
        "plan" => plan_cmd(args),
        "gc" => gc_cmd(args),
        "pretrain" => pretrain(args),
        "prune" => prune(args),
        "retrain" => retrain(args),
        "reconstruct" => reconstruct_cmd(args),
        "eval" => eval_cmd(args),
        "serve" => serve(args),
        "daemon" => daemon(args),
        "jobs" => jobs_cmd(args),
        "bench-serve" => bench_serve(args),
        "bench-spec" => bench_spec(args),
        "bench-kernels" => bench_kernels(args),
        "bench-graph" => bench_graph(args),
        "sweep" => sweep_cmd(args),
        "tables" => tables(args),
        other => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

const HELP: &str = "\
repro — PERP: Parameter-Efficient Retraining after Pruning (reproduction)

subcommands:
  info          list models, executables and the analytical memory table
  run           execute a pipeline plan or plan graph (--plan <file.json> or --stages \"...\")
  profile       run a plan and print per-stage wall clock + counter deltas;
                write results/profile.json
  plan          inspect a plan: plan show <file> [--dot] [--timings] — ASCII
                tree or Graphviz DOT with per-node cache-hit status (and
                recorded wall clock / counters with --timings)
  gc            reclaim stage artifacts unreachable from any plan file
                (--dry-run by default; --force deletes)
  pretrain      converge a dense model and cache the checkpoint
  prune         prune the cached dense model, report ppl collapse
  retrain       prune + retrain with a PERP mode, report recovery
  reconstruct   prune + layer-wise reconstruction (Eq. 1)
  eval          evaluate the cached dense model, or --from <ckpt> (ppl + zero-shot)
  serve         HTTP inference server with KV-cache decoding + dynamic batching
  daemon        durable experiment daemon: persistent plan-graph job queue
                under <out>/jobs/ with an HTTP API; survives restarts (jobs
                resume through the stage cache) and SIGINT/SIGTERM drains
                gracefully
  jobs          client for a running daemon:
                repro jobs submit --stages \"...\" | --plan <file> [--watch]
                repro jobs list | status <id> | cancel <id> | watch <id>
  bench-serve   load-generate against the batcher; write results/bench_serve.json
  bench-spec    plain vs speculative decoding across draft sparsities × K;
                write results/bench_spec.json (throughput, acceptance rate)
  bench-kernels dense/masked/CSR/BSR/quantised matmul A/B + the crossover
                table --layout auto consumes; write results/bench_kernels.json
  bench-graph   serial vs parallel plan-graph A/B; write results/bench_graph.json
  sweep         regenerate one paper table/figure (--exp <id>)
  tables        regenerate every table/figure

common flags:
  --model <name>       gpt-nano | gpt-tiny | gpt-small | llama-tiny  [gpt-tiny]
  --backend <b>        native | pjrt (pjrt needs the cargo feature)  [native]
  --profile <p>        quick | full                                 [quick]
  --artifacts <dir>    artifacts directory (pjrt backend only)       [./artifacts]
  --out <dir>          results + checkpoint cache                    [./results]
  --seed <n>           experiment seed                               [0]
  --threads <n>        rayon kernel threads (or PERP_THREADS)        [all cores]
  --jobs <j>           auto | K — concurrent plan-graph nodes; N in-flight
                       nodes split the kernel thread budget (or PERP_JOBS) [1]
  --layout <l>         sparse weight layout: auto | auto-q | dense | masked |
                       csr | bsr | csr-f16 | csr-q8 | bsr-f16 | bsr-q8  [auto]
                       (auto picks an exact layout per layer from the measured
                       crossover table in <out>/bench_kernels.json when present
                       — regenerate with `repro bench-kernels`; fallback
                       heuristic: bsr for 2:4 masks, csr at/above the
                       PERP_CSR_CROSSOVER sparsity, default 0.75.  auto-q may
                       also pick quantised layouts: approximate, eval/decode
                       only.  PERP_CROSSOVER_TABLE points at a table file)
  --criterion <c>      magnitude | magnitude-global | wanda | sparsegpt
  --sparsity <s>       0.5 | 50 | 2:4 | 4:8
  --mode <m>           full | biases | ln | biases_ln | head | embed |
                       lora | lora_prune | masklora | masklora_std | scalelora
  --steps <n>          override step counts
  --exp <id>           fig1 fig2 table1 table2 table3 table4 table5
                       table19 table20 table22 memory
  --trace              record hierarchical spans; written as Chrome
                       trace-events (+ .jsonl twin) to <out>/trace.json on
                       exit.  PERP_TRACE=1|<path> does the same from the
                       environment; PERP_LOG=debug|info|warn|off sets log
                       verbosity (off also silences progress lines)

run flags:
  --plan <file.json>   plan or plan-graph file (see examples/plans/)
  --stages <spec>      inline plan, e.g. \"prune(wanda,0.5)|retrain(masklora,100)|merge|eval\"
                       (a leading pretrain stage is implied).  Fan-out forms
                       build a graph: fork[a|b;c|d] runs each ;-branch off
                       the current leaves, seeds(n) replicates the path over
                       n consecutive seeds, agg reduces eval leaves to
                       mean±std
  --force              ignore completed stage artifacts; recompute everything

profile flags:
  --plan | --stages | --force   as for run; prints one row per stage node
                       (status, wall clock, counter deltas — recorded at
                       compute time and replayed for cache hits) and writes
                       <out>/profile.json

gc flags:
  --plans <dir>        plan/graph files defining reachability  [examples/plans]
  --keep <f1,f2>       extra plan files whose artifacts must survive
  --force              actually delete unreachable stage dirs (default: dry run)

eval flags:
  --from <ckpt>        evaluate a saved .ptns checkpoint (pruned/retrained/
                       merged artifacts) instead of the cached dense model

serve flags:
  --from <ckpt>        checkpoint to serve            [cached dense pretrain]
  --variants n=p,...   extra hot-loaded variants (name=checkpoint pairs)
  --draft <ckpt>       draft checkpoint for speculative decoding (greedy
                       streams only; typically a prune|retrain|merge product)
  --spec-k <n>         draft tokens per speculative round  [4, max spec_width-1]
  --host <h>           bind address                   [127.0.0.1]
  --port <p>           bind port                      [7777]
  --workers <n>        HTTP worker threads            [serve_slots + 2]
  --max-batch <n>      concurrent decode streams      [model serve_slots]

daemon flags:
  --host <h>           bind address                   [127.0.0.1]
  --port <p>           bind port                      [7766]
  --workers <n>        HTTP worker threads            [8]
  --job-workers <n>    concurrent job runners (each holds one kernel-budget
                       share, so parallel jobs split threads)        [2]

jobs flags:
  --host <h> --port <p>  daemon address                [127.0.0.1:7766]
  submit: --stages <spec> | --plan <file.json>, plus optional
          --name --model --profile --layout --seed --jobs <k> --watch
          (--watch polls until the job reaches a terminal state)

bench-serve flags:
  --requests <n>       total /generate requests per phase    [16]
  --max-tokens <n>     new tokens per request                [16]
  --concurrency <n>    concurrent clients (batched phase)    [8]
  --from <ckpt>        checkpoint to serve                   [cached dense]

bench-spec flags:
  --requests <n>       /generate requests per phase          [8]
  --max-tokens <n>     new tokens per request                [24]
  --sparsities <list>  draft sparsities to manufacture       [0.5,0.9]
  --ks <list>          speculative draft lengths             [2,4]
  --retrain-steps <n>  draft masklora retrain steps          [profile default]

bench-kernels flags:
  --shapes <list>      NxKxM GEMM shapes     [256x256x256,512x512x512,1024x256x1024]
  --sparsities <list>  fractions pruned      [0.5,0.7,0.9,0.95,0.99]
  --out <dir>          JSON output directory [./results]

bench-graph flags:
  --jobs <j>           worker count for the parallel phase  [auto, min 2]
  (plus the common model/profile/backend/out flags; the timed sweeps run
   in a scratch cache under --out and are removed afterwards)
";

struct Env {
    rt: Box<dyn Backend>,
    cfg: ExperimentConfig,
    out: PathBuf,
    seed: u64,
    /// concurrent plan-graph nodes (`--jobs`/`PERP_JOBS`; 1 = serial walk)
    jobs: usize,
}

fn common(args: &Args) -> Result<Env> {
    // size the kernel pool before the first rayon use anywhere
    perp::util::threads::configure(args.opt_usize("threads")?);
    let artifacts = args
        .opt_str("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let model = args.str("model", "gpt-tiny");
    let profile = args.str("profile", "quick");
    let mut cfg = ExperimentConfig::profile(&profile, &model)?;
    if let Some(cfg_file) = args.opt_str("config") {
        cfg = cfg.with_file(std::path::Path::new(&cfg_file))?;
    }
    if let Some(backend) = args.opt_str("backend") {
        cfg.backend = backend;
    }
    if let Some(policy) = args.opt_layout()? {
        cfg.layout = policy.name().to_string();
    }
    if let Some(steps) = args.opt_u64("steps")? {
        cfg.retrain_steps = steps;
    }
    if let Some(steps) = args.opt_u64("pretrain-steps")? {
        cfg.pretrain_steps = steps;
    }
    let kind = BackendKind::parse(&cfg.backend).map_err(|e| anyhow::anyhow!(e))?;
    let rt = open_backend(kind, &artifacts)?;
    let out = PathBuf::from(args.str("out", "results"));
    std::fs::create_dir_all(&out).ok();
    // advertise the measured crossover table (written by `repro
    // bench-kernels`) to the layout dispatcher; an explicit
    // PERP_CROSSOVER_TABLE always wins
    let table = out.join("bench_kernels.json");
    if std::env::var_os("PERP_CROSSOVER_TABLE").is_none() && table.is_file() {
        std::env::set_var("PERP_CROSSOVER_TABLE", &table);
    }
    // --jobs wins over PERP_JOBS; `auto` sizes to the kernel thread budget
    let jobs = match args.opt_jobs()? {
        Some(j) => j.resolve(),
        None => perp::util::threads::jobs_from_env().map_or(1, |j| j.resolve()),
    };
    // --trace or PERP_TRACE=1|<path> turns span recording on; the sink
    // defaults to <out>/trace.json and main() flushes it after dispatch
    let trace_env = perp::obs::trace::env_request();
    if args.flag("trace") || trace_env.is_some() {
        let sink = trace_env.flatten().unwrap_or_else(|| out.join("trace.json"));
        perp::obs::trace::configure(true, Some(sink));
    }
    Ok(Env { rt, cfg, out, seed: args.u64("seed", 0)?, jobs })
}

fn ctx(env: &Env) -> ExpContext<'_> {
    ExpContext::new(env.rt.as_ref(), env.cfg.clone(), env.out.join("cache")).jobs(env.jobs)
}

/// Plan executor over this environment — shims run quiet so their output
/// stays byte-compatible with the pre-plan subcommands.
fn executor(env: &Env) -> Executor<'_> {
    Executor::new(env.rt.as_ref(), env.cfg.clone(), env.out.join("cache"), env.seed)
        .jobs(env.jobs)
}

fn info(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish()?;
    println!(
        "backend: {} (manifest: {:?})",
        env.rt.kind(),
        env.rt.manifest().dir
    );
    for (name, mm) in &env.rt.manifest().models {
        println!(
            "  {name}: {} params, {} executables, d={} L={} V={} bias={} norm={}",
            mm.total_params(),
            mm.executables.len(),
            mm.cfg.d_model,
            mm.cfg.n_layers,
            mm.cfg.vocab,
            mm.cfg.use_bias,
            mm.cfg.norm,
        );
        for mode in ["ln", "biases", "masklora", "full"] {
            let cnt = mm.trainable_count(mode);
            println!(
                "     trainable[{mode}]: {cnt} ({:.3}%)",
                100.0 * cnt as f64 / mm.total_params() as f64
            );
        }
    }
    for t in sweep::run(&ctx(&env), "memory")? {
        t.print();
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Plans.
// ---------------------------------------------------------------------------

fn run_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let plan_file = args.opt_str("plan");
    let stages = args.opt_str("stages");
    let force = args.flag("force");
    args.finish()?;
    let loaded = match (&plan_file, &stages) {
        (Some(p), None) => PlanOrGraph::from_file(Path::new(p))?,
        (None, Some(s)) if spec_is_graph(s) => PlanOrGraph::Graph(
            parse_graph("inline", s).map_err(|e| anyhow::anyhow!(ArgError(e)))?,
        ),
        (None, Some(s)) => PlanOrGraph::Linear(
            parse_plan("inline", s).map_err(|e| anyhow::anyhow!(ArgError(e)))?,
        ),
        _ => {
            // a usage problem, not a runtime failure: exit 2 like other
            // argument errors
            return Err(anyhow::anyhow!(ArgError(
                "run needs exactly one of --plan <file.json> or --stages \"<spec>\"".to_string()
            )));
        }
    };
    let execs_before = env.rt.exec_count();
    match loaded {
        PlanOrGraph::Linear(plan) => {
            println!(
                "running plan {:?} ({} stages) on {} [{}]",
                plan.name,
                plan.stages.len(),
                env.cfg.model,
                env.rt.kind()
            );
            let report = executor(&env).force(force).run(&plan)?;
            println!("{}", report.summary());
            if let Some(m) = report.last_metrics() {
                if m.acc.is_nan() {
                    println!("final eval: test ppl {:.3} (sparsity {:.3})", m.ppl, m.sparsity);
                } else {
                    println!(
                        "final eval: test ppl {:.3}, mean zero-shot acc {:.1}% (sparsity {:.3})",
                        m.ppl,
                        m.acc * 100.0,
                        m.sparsity
                    );
                    for (name, acc) in &m.per_task {
                        println!("  {:>6}: {:.1}%", name, acc * 100.0);
                    }
                }
            }
        }
        PlanOrGraph::Graph(g) => {
            println!(
                "running plan graph {:?} ({} nodes, {} roots) on {} [{}]",
                g.name,
                g.stage_count(),
                g.roots().len(),
                env.cfg.model,
                env.rt.kind()
            );
            let report = executor(&env).force(force).run_graph(&g)?;
            println!("{}", report.summary());
            for node in &report.nodes {
                if let Some(m) = &node.rep.metrics {
                    println!(
                        "  {:<32} ppl {:.3} (sparsity {:.3}, seed {})",
                        node.name, m.ppl, m.sparsity, node.seed
                    );
                }
            }
            for agg in &report.aggregates {
                println!(
                    "aggregate {}: ppl {}  acc {}  sparsity {} (over {} leaves)",
                    agg.name,
                    agg.ppl.display(3),
                    agg.acc.display(3),
                    agg.sparsity.display(3),
                    agg.over.len()
                );
            }
        }
    }
    println!("backend executions: {}", env.rt.exec_count() - execs_before);
    Ok(())
}

/// `repro profile` — run a plan (cold or cache-warm) and report per-stage
/// wall clock and counter deltas.  Cache hits replay the observations
/// recorded when the stage was first computed (the `plan/<key>.prof.json`
/// sidecars), so profiling an already-built cache is instant.
fn profile_cmd(args: &Args) -> Result<()> {
    use perp::obs::counters::Registry;
    use perp::util::bench::Table;

    let env = common(args)?;
    let plan_file = args.opt_str("plan");
    let stages = args.opt_str("stages");
    let force = args.flag("force");
    args.finish()?;
    let loaded = match (&plan_file, &stages) {
        (Some(p), None) => PlanOrGraph::from_file(Path::new(p))?,
        (None, Some(s)) if spec_is_graph(s) => PlanOrGraph::Graph(
            parse_graph("inline", s).map_err(|e| anyhow::anyhow!(ArgError(e)))?,
        ),
        (None, Some(s)) => PlanOrGraph::Linear(
            parse_plan("inline", s).map_err(|e| anyhow::anyhow!(ArgError(e)))?,
        ),
        _ => {
            return Err(anyhow::anyhow!(ArgError(
                "profile needs exactly one of --plan <file.json> or --stages \"<spec>\""
                    .to_string()
            )));
        }
    };
    let g = loaded.graph();

    let snap0 = Registry::global().snapshot();
    let t0 = Instant::now();
    let report = executor(&env).force(force).quiet(true).run_graph(&g)?;
    let wall_s = t0.elapsed().as_secs_f64();
    let run_deltas = Registry::global().snapshot().since(&snap0);

    let mut t = Table::new(
        &format!("profile: {} on {} ({} jobs)", g.name, env.cfg.model, env.jobs),
        &["node", "stage", "status", "wall", "counters"],
    );
    for n in &report.nodes {
        let status = if n.rep.cache_hit { "cached" } else { "computed" };
        // a hit's wall_s is just lookup time; prefer the recorded compute wall
        let wall = n.rep.computed_wall_s.unwrap_or(n.rep.wall_s);
        t.row(vec![
            n.name.clone(),
            n.rep.label.clone(),
            status.to_string(),
            format!("{wall:.2}s"),
            fmt_counter_deltas(&n.rep.counters, 3),
        ]);
    }
    t.print();
    println!(
        "run: {wall_s:.2}s wall, {} of {} nodes computed",
        report.computed(),
        g.stage_count()
    );
    if !run_deltas.counters.is_empty() {
        println!(
            "process counters this run: {}",
            fmt_counter_deltas(&run_deltas.counters, 6)
        );
    }

    let counters_json = |c: &std::collections::BTreeMap<String, u64>| {
        Json::obj(c.iter().map(|(k, &v)| (k.as_str(), Json::Num(v as f64))).collect())
    };
    let nodes = Json::Arr(
        report
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("node", Json::Str(n.name.clone())),
                    ("stage", Json::Str(n.rep.label.clone())),
                    ("seed", Json::Num(n.seed as f64)),
                    ("cache_hit", Json::Bool(n.rep.cache_hit)),
                    ("wall_s", Json::Num(n.rep.computed_wall_s.unwrap_or(n.rep.wall_s))),
                    ("counters", counters_json(&n.rep.counters)),
                ])
            })
            .collect(),
    );
    let j = Json::obj(vec![
        ("graph", Json::Str(g.name.clone())),
        ("model", Json::Str(env.cfg.model.clone())),
        ("jobs", Json::Num(env.jobs as f64)),
        ("wall_s", Json::Num(wall_s)),
        ("nodes", nodes),
        ("counters", counters_json(&run_deltas.counters)),
    ]);
    let path = env.out.join("profile.json");
    std::fs::write(&path, j.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {path:?}");
    Ok(())
}

/// The `k` largest counter deltas as space-joined `name=v` pairs (`-` when
/// there are none; ties break alphabetically for stable output).
fn fmt_counter_deltas(counters: &std::collections::BTreeMap<String, u64>, k: usize) -> String {
    if counters.is_empty() {
        return "-".to_string();
    }
    let mut pairs: Vec<(&String, &u64)> = counters.iter().collect();
    pairs.sort_by(|a, b| b.1.cmp(a.1).then_with(|| a.0.cmp(b.0)));
    pairs.truncate(k);
    pairs.iter().map(|(n, v)| format!("{n}={v}")).collect::<Vec<_>>().join(" ")
}

// ---------------------------------------------------------------------------
// Plan inspection + cache garbage collection.
// ---------------------------------------------------------------------------

fn plan_cmd(args: &Args) -> Result<()> {
    match args.pos(0) {
        Some("show") => plan_show(args),
        other => Err(anyhow::anyhow!(ArgError(format!(
            "plan expects the 'show' action (repro plan show <file> [--dot]), got {other:?}"
        )))),
    }
}

fn plan_show(args: &Args) -> Result<()> {
    let env = common(args)?;
    let file = args.pos(1).map(str::to_string).ok_or_else(|| {
        anyhow::anyhow!(ArgError("plan show needs a file: repro plan show <file> [--dot]".into()))
    })?;
    let dot = args.flag("dot");
    let timings = args.flag("timings");
    args.finish()?;

    let g = PlanOrGraph::from_file(Path::new(&file))?.graph();
    g.validate()
        .map_err(|e| anyhow::anyhow!("invalid plan {file:?}: {e}"))?;
    let keys = g
        .node_keys(&env.cfg, env.seed)
        .map_err(|e| anyhow::anyhow!("keying plan {file:?}: {e}"))?;
    let cache = env.out.join("cache");
    // per-node cache status under the current (model, profile, seed): what a
    // re-run would load vs actually execute; --timings appends the wall
    // clock and busiest counters recorded when the stage was computed
    let annotate = |n: &perp::pipeline::Node| -> String {
        match n.stage() {
            None => String::new(),
            Some(stage) => {
                let key = keys[&n.name];
                let status = if stage_complete(&stage_dir(&cache, &key), stage) {
                    "cached"
                } else {
                    "pending"
                };
                let mut tag = format!("[{status} {}]", &key.hex()[..10]);
                if timings {
                    if let Some((wall, counters)) = recorded_profile(&cache, &key) {
                        if let Some(w) = wall {
                            tag.push_str(&format!(" {w:.2}s"));
                        }
                        if !counters.is_empty() {
                            tag.push_str(&format!(" ({})", fmt_counter_deltas(&counters, 2)));
                        }
                    }
                }
                tag
            }
        }
    };
    if dot {
        print!("{}", g.render_dot(&annotate));
    } else {
        let cached = g
            .nodes
            .iter()
            .filter(|n| {
                n.stage().is_some_and(|s| stage_complete(&stage_dir(&cache, &keys[&n.name]), s))
            })
            .count();
        println!(
            "plan {:?}: {} stage nodes ({} cached under {:?}), {} roots",
            g.name,
            g.stage_count(),
            cached,
            cache,
            g.roots().len()
        );
        print!("{}", g.render_tree(&annotate));
    }
    Ok(())
}

/// Recursive directory size in bytes.
fn dir_size(path: &Path) -> u64 {
    let Ok(entries) = std::fs::read_dir(path) else {
        return 0;
    };
    entries
        .flatten()
        .map(|e| {
            let p = e.path();
            match e.metadata() {
                Ok(md) if md.is_dir() => dir_size(&p),
                Ok(md) => md.len(),
                Err(_) => 0,
            }
        })
        .sum()
}

fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} bytes")
    }
}

/// `repro gc` — reclaim stage artifacts unreachable from any plan/graph
/// file.  Reachability is computed for the *current* (model, profile,
/// backend) over every seed in the profile plus --seed, so run it with the
/// same flags as the runs whose artifacts you want kept.  Dry-run by
/// default; `--force` deletes.
fn gc_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let plans_dir = PathBuf::from(args.str("plans", "examples/plans"));
    let keep: Vec<String> = args.list("keep", "");
    let delete = args.flag("force");
    args.finish()?;

    // collect every plan/graph file that pins artifacts
    let mut files: Vec<PathBuf> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&plans_dir) {
        for e in entries.flatten() {
            let p = e.path();
            if p.extension().is_some_and(|x| x == "json") {
                files.push(p);
            }
        }
    }
    files.sort();
    files.extend(keep.iter().map(PathBuf::from));

    // reachable = every node key of every file, across the profile's seeds
    // and the CLI seed (graphs add their own seed offsets on top)
    let mut seeds: Vec<u64> = env.cfg.seeds.clone();
    if !seeds.contains(&env.seed) {
        seeds.push(env.seed);
    }
    let mut reachable: std::collections::BTreeSet<String> = Default::default();
    for file in &files {
        let g = PlanOrGraph::from_file(file)
            .with_context(|| format!("gc: unreadable plan file {file:?}"))?
            .graph();
        g.validate()
            .map_err(|e| anyhow::anyhow!("gc: invalid plan {file:?}: {e}"))?;
        for &seed in &seeds {
            let keys = g
                .node_keys(&env.cfg, seed)
                .map_err(|e| anyhow::anyhow!("gc: keying {file:?}: {e}"))?;
            reachable.extend(keys.values().map(|k| k.hex()));
        }
    }

    // the job store pins artifacts too: a queued/interrupted job must find
    // its completed stages in the cache when the daemon resumes it, so every
    // node key of every non-terminal job is a root
    let mut job_pins = 0usize;
    let jobs_root = env.out.join("jobs");
    if jobs_root.is_dir() {
        for rec in JobStore::open(&jobs_root)?.list().context("gc: reading job store")? {
            if rec.status.is_terminal() {
                continue;
            }
            job_pins += 1;
            reachable.extend(rec.nodes.values().map(|n| n.key.clone()));
        }
    }

    let plan_cache = env.out.join("cache").join("plan");
    let mut unreachable: Vec<(PathBuf, u64)> = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&plan_cache) {
        for e in entries.flatten() {
            let p = e.path();
            let name = e.file_name().to_string_lossy().to_string();
            // stage dirs are 16-hex keys; `.tmp-*` staging dirs are
            // leftovers from killed runs (a live run renames its staging
            // dir away before finishing) — both are reclaimable, anything
            // else is left alone
            let is_key = name.len() == 16 && name.chars().all(|c| c.is_ascii_hexdigit());
            let is_stale_tmp = name.starts_with(".tmp-");
            if p.is_dir() && (is_stale_tmp || (is_key && !reachable.contains(&name))) {
                let size = dir_size(&p);
                unreachable.push((p, size));
            }
        }
    }
    unreachable.sort();

    let total: u64 = unreachable.iter().map(|(_, s)| s).sum();
    println!(
        "gc: {} plan files + {} live jobs pin {} stage keys under {:?} (seeds {:?})",
        files.len(),
        job_pins,
        reachable.len(),
        plan_cache,
        seeds
    );
    for (p, size) in &unreachable {
        println!("  unreachable {:?} ({})", p.file_name().unwrap_or_default(), fmt_bytes(*size));
    }
    if delete {
        for (p, _) in &unreachable {
            std::fs::remove_dir_all(p).with_context(|| format!("gc: deleting {p:?}"))?;
        }
        println!(
            "gc: {} unreachable stage dirs deleted, {} reclaimed",
            unreachable.len(),
            fmt_bytes(total)
        );
    } else {
        println!(
            "gc: {} unreachable stage dirs, {} reclaimable (dry run — pass --force to delete)",
            unreachable.len(),
            fmt_bytes(total)
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Shim subcommands: each builds a small plan and prints the classic lines.
// ---------------------------------------------------------------------------

fn pretrain(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish()?;
    let plan = Plan::new("pretrain").pretrain();
    let (_, s) = executor(&env).quiet(true).run_with_session(&plan)?;
    let ppl = s.eval_ppl_test()?;
    println!(
        "dense {}: test ppl {:.3} (loss {:.4}), last train tps {:.0}",
        env.cfg.model, ppl.ppl, ppl.loss, s.last_tps
    );
    Ok(())
}

fn parse_prune(args: &Args) -> Result<(Criterion, Pattern)> {
    let crit = Criterion::parse(&args.str("criterion", "magnitude"))
        .map_err(|e| anyhow::anyhow!(ArgError(e)))?;
    let pattern =
        Pattern::parse(&args.str("sparsity", "0.5")).map_err(|e| anyhow::anyhow!(ArgError(e)))?;
    Ok((crit, pattern))
}

fn prune(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    args.finish()?;
    let plan = Plan::new("prune").pretrain().prune(crit, pattern);
    let (_, s) = executor(&env).quiet(true).run_with_session(&plan)?;
    let ppl = s.eval_ppl_test()?;
    println!(
        "{} @ {} ({}): achieved sparsity {:.3}, test ppl {:.2}",
        crit.name(),
        pattern.label(),
        env.cfg.model,
        s.masks.sparsity(),
        ppl.ppl
    );
    s.save(&env.out.join("pruned.ptns"))?;
    Ok(())
}

fn retrain(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    let mode =
        Mode::parse(&args.str("mode", "masklora")).map_err(|e| anyhow::anyhow!(ArgError(e)))?;
    args.finish()?;
    let ex = executor(&env).quiet(true);
    // pruned baseline; its stages are the prefix of the full plan below, so
    // the second run loads them from the cache instead of pruning twice
    let base_plan = Plan::new("retrain-base").pretrain().prune(crit, pattern);
    let (_, pruned) = ex.run_with_session(&base_plan)?;
    let before = pruned.eval_ppl_test()?;

    let mut plan = Plan::new("retrain")
        .pretrain()
        .prune(crit, pattern)
        .retrain(mode, None, None);
    if mode.is_lora() && mode != Mode::Lora {
        // standard LoRA stays unmerged (Table 2's "Mergeable: no")
        plan = plan.merge();
    }
    let (report, s) = ex.run_with_session(&plan)?;
    let after = s.eval_ppl_test()?;
    let acc = perp::eval::mean_accuracy(&s.eval_tasks()?);
    let tps = report.stages.iter().rev().find_map(|r| r.tps).unwrap_or(0.0);
    let pct = report
        .stages
        .iter()
        .rev()
        .find_map(|r| r.trainable_pct)
        .unwrap_or(0.0);
    // the lr the stage actually used (grid-tuned when lr_grid has >1 entry)
    let lr = report
        .stages
        .iter()
        .rev()
        .find_map(|r| r.lr)
        .unwrap_or(env.cfg.lr_grid[0]);
    println!(
        "{} @ {} + {} ({} steps, lr {lr}): ppl {:.2} -> {:.2}, acc {:.1}%, tps {:.0}, trainable {:.3}%",
        crit.name(),
        pattern.label(),
        mode.name(),
        env.cfg.retrain_steps,
        before.ppl,
        after.ppl,
        acc * 100.0,
        tps,
        pct
    );
    Ok(())
}

fn reconstruct_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let (crit, pattern) = parse_prune(args)?;
    let recon_mode = match args.str("recon-mode", "masklora").as_str() {
        "masklora" => ReconMode::MaskLora,
        "full" => ReconMode::FullFt,
        other => {
            return Err(anyhow::anyhow!(ArgError(format!(
                "--recon-mode expects masklora|full, got {other:?}"
            ))))
        }
    };
    args.finish()?;
    let ex = executor(&env).quiet(true);
    let base_plan = Plan::new("recon-base").pretrain().prune(crit, pattern);
    let (_, pruned) = ex.run_with_session(&base_plan)?;
    let before = pruned.eval_ppl_test()?;

    let plan = Plan::new("reconstruct")
        .pretrain()
        .prune(crit, pattern)
        .reconstruct(recon_mode, None, None);
    let (report, s) = ex.run_with_session(&plan)?;
    let after = s.eval_ppl_test()?;
    let acc = perp::eval::mean_accuracy(&s.eval_tasks()?);
    let mean_impr = report
        .stages
        .iter()
        .rev()
        .find_map(|r| r.mean_improvement)
        .unwrap_or(0.0);
    println!(
        "{} @ {} + reconstruction: ppl {:.2} -> {:.2}, acc {:.1}%, mean layer-loss drop {:.4}",
        crit.name(),
        pattern.label(),
        before.ppl,
        after.ppl,
        acc * 100.0,
        mean_impr
    );
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let from = args.opt_str("from");
    args.finish()?;
    let s = match &from {
        // evaluate a saved artifact (pruned / retrained / merged) directly
        Some(path) => {
            Session::from_checkpoint(env.rt.as_ref(), env.cfg.clone(), env.seed, Path::new(path))?
        }
        None => {
            executor(&env)
                .quiet(true)
                .run_with_session(&Plan::new("eval").pretrain())?
                .1
        }
    };
    let ppl = s.eval_ppl_test()?;
    let tasks = s.eval_tasks()?;
    match &from {
        Some(path) => println!(
            "{} (from {path}, sparsity {:.3}): test ppl {:.3}",
            env.cfg.model,
            s.params.weight_sparsity(&s.mm),
            ppl.ppl
        ),
        None => println!("{}: test ppl {:.3}", env.cfg.model, ppl.ppl),
    }
    for t in &tasks {
        println!("  {:>6}: {:.1}% ({} items)", t.name, t.accuracy * 100.0, t.items);
    }
    println!("  mean zero-shot acc: {:.1}%", perp::eval::mean_accuracy(&tasks) * 100.0);
    Ok(())
}

fn run_and_record(env: &Env, exp: &str) -> Result<()> {
    let c = ctx(env);
    let t0 = std::time::Instant::now();
    let tables = sweep::run(&c, exp)?;
    let path = env.out.join(format!("{exp}.md"));
    let _ = std::fs::remove_file(&path);
    for t in &tables {
        t.print();
        t.append_to(&path)?;
    }
    perp::util::logging::progress(&format!(
        "[{exp}] done in {:.1}s -> {path:?}",
        t0.elapsed().as_secs_f64()
    ));
    Ok(())
}

fn sweep_cmd(args: &Args) -> Result<()> {
    let env = common(args)?;
    let exp = args.str("exp", "");
    args.finish()?;
    if exp.is_empty() {
        bail!("--exp required; one of {:?}", sweep::EXPERIMENTS);
    }
    run_and_record(&env, &exp)
}

fn tables(args: &Args) -> Result<()> {
    let env = common(args)?;
    args.finish()?;
    for exp in sweep::EXPERIMENTS {
        run_and_record(&env, exp)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Serving.
// ---------------------------------------------------------------------------

fn serve(args: &Args) -> Result<()> {
    let env = common(args)?;
    let host = args.str("host", "127.0.0.1");
    let port = args.usize("port", 7777)?;
    let workers = args.opt_usize("workers")?;
    let max_batch = args.opt_usize("max-batch")?;
    let from = args.opt_str("from").map(PathBuf::from);
    let variants = args.opt_str("variants");
    let draft = args.opt_str("draft").map(PathBuf::from);
    let spec_k = args.usize("spec-k", 4)?;
    args.finish()?;
    if draft.is_some() && spec_k == 0 {
        bail!("--spec-k must be >= 1 when --draft is given");
    }

    let cache_dir = env.out.join("cache");
    let mut batch = BatchCfg::default();
    if let Some(mb) = max_batch {
        batch.max_active = mb;
    }
    let state = Arc::new(ServeState::new(
        env.cfg.model.clone(),
        env.cfg.clone(),
        cache_dir.clone(),
        env.seed,
    ));
    // default engine carries the model's name; extra variants ride along
    let handle = batcher::spawn(EngineSpec {
        name: env.cfg.model.clone(),
        cfg: env.cfg.clone(),
        seed: env.seed,
        checkpoint: from,
        cache_dir: cache_dir.clone(),
        batch: batch.clone(),
        draft,
        spec_k,
    })?;
    state.insert(handle)?;
    if let Some(pairs) = variants {
        for pair in pairs.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, path) = pair
                .split_once('=')
                .context("--variants expects name=checkpoint[,name=checkpoint...]")?;
            let handle = batcher::spawn(EngineSpec {
                name: name.trim().to_string(),
                cfg: env.cfg.clone(),
                seed: env.seed,
                checkpoint: Some(PathBuf::from(path.trim())),
                cache_dir: cache_dir.clone(),
                batch: batch.clone(),
                draft: None,
                spec_k: 0,
            })?;
            state.insert(handle)?;
        }
    }

    // every /generate occupies one HTTP worker end-to-end, so the pool must
    // be at least as wide as the decode batch or the batcher can never fill
    let slots = env.rt.model(&env.cfg.model)?.cfg.serve_slots;
    let workers = workers.unwrap_or(slots.max(8) + 2);
    let server = Server::bind(state.clone(), &format!("{host}:{port}"), workers)?;
    println!("perp-serve listening on http://{}", server.addr);
    println!("  GET  /healthz /metrics /models");
    println!("  POST /generate /score /models/load /shutdown");
    server.run();
    state.shutdown();
    Ok(())
}

// ---------------------------------------------------------------------------
// The experiment daemon + its CLI client.
// ---------------------------------------------------------------------------

/// POSIX signal plumbing without a libc dependency: `signal(2)` installs a
/// handler that does nothing but set one atomic flag (the only
/// async-signal-safe thing worth doing).  glibc's `signal()` semantics are
/// `SA_RESTART`, so a blocking accept is *not* interrupted — the daemon
/// polls the flag from a watchdog thread instead.
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static STOP: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    /// Install SIGINT/SIGTERM handlers that set the stop flag.
    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn stop_requested() -> bool {
        STOP.load(Ordering::SeqCst)
    }
}

/// `repro daemon` — boot the durable job queue and serve the `/jobs` API.
/// Jobs run on `--job-workers` runner threads that share the kernel-thread
/// budget with each other.  SIGINT/SIGTERM (or `POST /shutdown`) drains
/// gracefully: dequeuing stops, in-flight nodes finish, and interrupted
/// jobs requeue themselves for the next boot, where they resume through
/// the content-addressed stage cache.
fn daemon(args: &Args) -> Result<()> {
    let env = common(args)?;
    let host = args.str("host", "127.0.0.1");
    let port = args.usize("port", 7766)?;
    let http_workers = args.usize("workers", 8)?.max(1);
    let job_workers = args.usize("job-workers", 2)?.max(1);
    args.finish()?;

    let cache_dir = env.out.join("cache");
    let manager = Arc::new(JobManager::open(&env.out.join("jobs"))?);
    let state = Arc::new(ServeState::new(
        env.cfg.model.clone(),
        env.cfg.clone(),
        cache_dir.clone(),
        env.seed,
    ));
    state.set_jobs(manager.clone());
    let server = Server::bind(state.clone(), &format!("{host}:{port}"), http_workers)?;
    sig::install();
    println!("perp-daemon listening on http://{}", server.addr);
    println!("  GET  /healthz /metrics /jobs /jobs/<id>");
    println!("  POST /jobs /jobs/<id>/cancel /shutdown");
    println!(
        "  job store {:?}, {job_workers} job workers, model {} [{}]",
        manager.store().root(),
        env.cfg.model,
        env.rt.kind()
    );

    std::thread::scope(|scope| {
        for i in 0..job_workers {
            let runner = JobRunner::new(env.rt.as_ref(), cache_dir.clone(), manager.clone());
            std::thread::Builder::new()
                .name(format!("job-worker-{i}"))
                .spawn_scoped(scope, move || runner.run())
                .expect("spawning job worker");
        }
        // signal watchdog: the handlers only set sig::STOP (async-signal-
        // safe); this thread turns that into a full request_shutdown, which
        // stops the queue and wakes the blocking accept loop
        let wd_state = state.clone();
        scope.spawn(move || {
            while !wd_state.stop.load(Ordering::Relaxed) {
                if sig::stop_requested() {
                    perp::server::request_shutdown(&wd_state);
                    break;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        });
        server.run();
        // run() also exits on POST /shutdown — make sure the queue stopped
        // either way so the runner threads drain and the scope can close
        perp::server::request_shutdown(&state);
    });
    state.shutdown();
    println!("perp-daemon stopped (in-flight nodes finished; interrupted jobs requeued)");
    Ok(())
}

/// `repro jobs` — thin HTTP client for a running daemon.
fn jobs_cmd(args: &Args) -> Result<()> {
    let action = args.pos(0).unwrap_or("").to_string();
    let host = args.str("host", "127.0.0.1");
    let port = args.usize("port", 7766)?;
    let addr = resolve_addr(&host, port)?;
    match action.as_str() {
        "submit" => jobs_submit(args, addr),
        "list" => {
            args.finish()?;
            jobs_list(addr)
        }
        "status" | "cancel" | "watch" => {
            let id = args.pos(1).map(str::to_string).ok_or_else(|| {
                anyhow::anyhow!(ArgError(format!("jobs {action} needs a job id")))
            })?;
            args.finish()?;
            match action.as_str() {
                "status" => jobs_status(addr, &id),
                "cancel" => jobs_cancel(addr, &id),
                _ => jobs_watch(addr, &id),
            }
        }
        other => Err(anyhow::anyhow!(ArgError(format!(
            "jobs expects an action (submit|list|status|cancel|watch), got {other:?}"
        )))),
    }
}

fn resolve_addr(host: &str, port: usize) -> Result<std::net::SocketAddr> {
    use std::net::ToSocketAddrs;
    format!("{host}:{port}")
        .to_socket_addrs()
        .with_context(|| format!("resolving {host}:{port}"))?
        .next()
        .with_context(|| format!("no address for {host}:{port}"))
}

fn jobs_submit(args: &Args, addr: std::net::SocketAddr) -> Result<()> {
    let mut fields: Vec<(&str, Json)> = Vec::new();
    match (args.opt_str("plan"), args.opt_str("stages")) {
        (Some(p), None) => {
            // normalise linear plan files to graphs client-side, like run
            let g = PlanOrGraph::from_file(Path::new(&p))?.graph();
            fields.push(("plan", g.to_json()));
        }
        (None, Some(s)) => fields.push(("stages", Json::Str(s))),
        _ => {
            return Err(anyhow::anyhow!(ArgError(
                "jobs submit needs exactly one of --plan <file.json> or --stages \"<spec>\""
                    .to_string()
            )))
        }
    }
    for key in ["name", "model", "profile"] {
        if let Some(v) = args.opt_str(key) {
            fields.push((key, Json::Str(v)));
        }
    }
    // validate client-side so a typo exits 2 here, not as a failed job
    if let Some(policy) = args.opt_layout()? {
        fields.push(("layout", Json::Str(policy.name().to_string())));
    }
    if let Some(seed) = args.opt_u64("seed")? {
        fields.push(("seed", Json::Num(seed as f64)));
    }
    if let Some(jobs) = args.opt_usize("jobs")? {
        fields.push(("jobs", Json::Num(jobs as f64)));
    }
    let watch = args.flag("watch");
    args.finish()?;
    let (status, resp) = client::post_json(addr, "/jobs", &Json::obj(fields))?;
    if status != 200 {
        bail!("submit rejected ({status}): {resp}");
    }
    let id = resp
        .get("id")
        .and_then(Json::as_str)
        .context("daemon response missing \"id\"")?
        .to_string();
    println!("submitted {id}");
    if watch {
        jobs_watch(addr, &id)?;
    }
    Ok(())
}

fn jobs_list(addr: std::net::SocketAddr) -> Result<()> {
    let (status, body) = client::get(addr, "/jobs")?;
    if status != 200 {
        bail!("GET /jobs failed ({status}): {body}");
    }
    let j = Json::parse(&body).map_err(|e| anyhow::anyhow!("parsing response: {e}"))?;
    let jobs = j.get("jobs").and_then(Json::as_arr).context("response missing \"jobs\"")?;
    if jobs.is_empty() {
        println!("no jobs");
        return Ok(());
    }
    println!("{:<8} {:<10} {:>7} {:>8}  name", "id", "status", "nodes", "attempts");
    for job in jobs {
        println!(
            "{:<8} {:<10} {:>3}/{:<3} {:>8}  {}",
            job.str_or("id", "?"),
            job.str_or("status", "?"),
            job.get("nodes_done").and_then(Json::as_i64).unwrap_or(0),
            job.get("nodes_total").and_then(Json::as_i64).unwrap_or(0),
            job.get("attempts").and_then(Json::as_i64).unwrap_or(0),
            job.str_or("name", "?"),
        );
    }
    Ok(())
}

fn fetch_job(addr: std::net::SocketAddr, id: &str) -> Result<Json> {
    let (status, body) = client::get(addr, &format!("/jobs/{id}"))?;
    if status != 200 {
        bail!("GET /jobs/{id} failed ({status}): {body}");
    }
    Json::parse(&body).map_err(|e| anyhow::anyhow!("parsing response: {e}"))
}

/// `(done, total)` stage-node counts out of a job-detail body.
fn job_progress(j: &Json) -> (usize, usize) {
    let nodes = j.get("nodes").and_then(Json::as_obj);
    let total = nodes.map_or(0, |m| m.len());
    let done = nodes.map_or(0, |m| {
        m.values()
            .filter(|n| n.get("status").and_then(Json::as_str) == Some("done"))
            .count()
    });
    (done, total)
}

fn jobs_status(addr: std::net::SocketAddr, id: &str) -> Result<()> {
    let j = fetch_job(addr, id)?;
    let (done, total) = job_progress(&j);
    println!(
        "{} ({}): {} — {done}/{total} nodes, {} attempts",
        j.str_or("id", "?"),
        j.str_or("name", "?"),
        j.str_or("status", "?"),
        j.get("attempts").and_then(Json::as_i64).unwrap_or(0)
    );
    if let Some(nodes) = j.get("nodes").and_then(Json::as_obj) {
        for (name, n) in nodes {
            let wall = n
                .get("wall_s")
                .and_then(Json::as_f64)
                .map(|w| format!(" {w:.2}s"))
                .unwrap_or_default();
            let hit = if n.get("cache_hit").and_then(Json::as_bool).unwrap_or(false) {
                " (cached)"
            } else {
                ""
            };
            println!(
                "  {:<28} {:<8} {}{wall}{hit}",
                name,
                n.str_or("status", "?"),
                n.str_or("label", "")
            );
        }
    }
    if let Some(aggs) = j.get("aggregates").and_then(Json::as_arr) {
        for a in aggs {
            let mean = |k: &str| {
                a.get(k)
                    .and_then(|v| v.get("mean"))
                    .and_then(Json::as_f64)
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".to_string())
            };
            println!(
                "  aggregate {}: ppl {} acc {} sparsity {}",
                a.str_or("name", "?"),
                mean("ppl"),
                mean("acc"),
                mean("sparsity")
            );
        }
    }
    if let Some(err) = j.get("error").and_then(Json::as_str) {
        println!("  error: {err}");
    }
    if let Some(warnings) = j.get("warnings").and_then(Json::as_arr) {
        for w in warnings.iter().filter_map(Json::as_str) {
            println!("  warning: {w}");
        }
    }
    Ok(())
}

fn jobs_cancel(addr: std::net::SocketAddr, id: &str) -> Result<()> {
    let (status, resp) =
        client::post_json(addr, &format!("/jobs/{id}/cancel"), &Json::obj(vec![]))?;
    if status != 200 {
        bail!("cancel failed ({status}): {resp}");
    }
    println!("{id}: {}", resp.str_or("result", "cancelled"));
    Ok(())
}

/// Poll every 2s until the job reaches a terminal state; nonzero exit
/// unless that state is `done`.  One keep-alive connection serves the
/// whole watch instead of a fresh TCP dial per poll.
fn jobs_watch(addr: std::net::SocketAddr, id: &str) -> Result<()> {
    let mut conn = client::Conn::new(addr);
    loop {
        let (status_code, body) = conn.get(&format!("/jobs/{id}"))?;
        if status_code != 200 {
            bail!("GET /jobs/{id} failed ({status_code}): {body}");
        }
        let j = Json::parse(&body).map_err(|e| anyhow::anyhow!("parsing response: {e}"))?;
        let status = j.str_or("status", "?");
        let (done, total) = job_progress(&j);
        println!("{id}: {status} ({done}/{total} nodes)");
        match status.as_str() {
            "done" => return Ok(()),
            "failed" | "cancelled" => match j.get("error").and_then(Json::as_str) {
                Some(err) => bail!("job {id} {status}: {err}"),
                None => bail!("job {id} {status}"),
            },
            _ => std::thread::sleep(Duration::from_secs(2)),
        }
    }
}

struct PhaseStats {
    tokens: u64,
    wall_s: f64,
    tps: f64,
    mean_ms: f64,
    p50_ms: f64,
    p95_ms: f64,
}

fn bench_phase(
    addr: std::net::SocketAddr,
    model: &str,
    requests: usize,
    concurrency: usize,
    max_tokens: usize,
) -> Result<PhaseStats> {
    let samples: Mutex<Vec<(f64, u64)>> = Mutex::new(Vec::with_capacity(requests));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        let samples = &samples;
        let errors = &errors;
        for w in 0..concurrency {
            let share = requests / concurrency + usize::from(w < requests % concurrency);
            scope.spawn(move || {
                // one keep-alive socket per worker for the whole phase
                let mut conn = client::Conn::new(addr);
                for i in 0..share {
                    let body = Json::obj(vec![
                        ("prompt", Json::Str(format!("the model serves request {w} {i}"))),
                        ("model", Json::Str(model.to_string())),
                        ("max_tokens", Json::Num(max_tokens as f64)),
                    ]);
                    let t = Instant::now();
                    match conn.post_json("/generate", &body) {
                        Ok((200, j)) => {
                            let toks = j
                                .get("tokens")
                                .and_then(Json::as_arr)
                                .map(|a| a.len() as u64)
                                .unwrap_or(0);
                            samples
                                .lock()
                                .unwrap()
                                .push((t.elapsed().as_secs_f64() * 1e3, toks));
                        }
                        Ok((code, j)) => {
                            errors.lock().unwrap().push(format!("status {code}: {j}"))
                        }
                        Err(e) => errors.lock().unwrap().push(format!("{e:#}")),
                    }
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let errors = errors.into_inner().unwrap();
    if !errors.is_empty() {
        bail!("bench requests failed ({} total): {}", errors.len(), errors[0]);
    }
    let samples = samples.into_inner().unwrap();
    let tokens: u64 = samples.iter().map(|&(_, t)| t).sum();
    let mut lats: Vec<f64> = samples.iter().map(|&(l, _)| l).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // latencies also feed the obs registry so `/metrics`-style snapshots of
    // a bench process carry the same distribution the table reports
    for &l in &lats {
        perp::obs::counters::Registry::global().observe("bench.latency_ms", l);
    }
    Ok(PhaseStats {
        tokens,
        wall_s,
        tps: tokens as f64 / wall_s.max(1e-9),
        mean_ms: lats.iter().sum::<f64>() / lats.len().max(1) as f64,
        p50_ms: perp::obs::counters::percentile(&lats, 0.50),
        p95_ms: perp::obs::counters::percentile(&lats, 0.95),
    })
}

// ---------------------------------------------------------------------------
// Kernel benchmarks: dense vs masked vs CSR.
// ---------------------------------------------------------------------------

struct KernelRow {
    op: &'static str,
    shape: String,
    /// Mask structure: "unstructured" or "2:4".
    pattern: &'static str,
    sparsity: f64,
    dense_ns: f64,
    masked_ns: f64,
    csr_ns: f64,
    bsr_ns: f64,
    /// Quantised forward variants (`None` on backward rows — quantised
    /// layouts have no backward).
    csr_f16_ns: Option<f64>,
    csr_q8_ns: Option<f64>,
    bsr_f16_ns: Option<f64>,
    bsr_q8_ns: Option<f64>,
    /// Resident value bytes per compressed layout (forward rows only).
    bytes: Option<ValueBytes>,
}

struct ValueBytes {
    csr: usize,
    bsr: usize,
    csr_q8: usize,
    bsr_q8: usize,
}

impl KernelRow {
    fn vs_masked(&self) -> f64 {
        self.masked_ns / self.csr_ns.max(1e-9)
    }
    fn vs_dense(&self) -> f64 {
        self.dense_ns / self.csr_ns.max(1e-9)
    }
    fn bsr_vs_csr(&self) -> f64 {
        self.csr_ns / self.bsr_ns.max(1e-9)
    }
}

/// Nearest ancestor of the cwd holding `file` — how the bench finds the
/// committed `BENCH_kernels.json` baseline whether it runs from the repo
/// root or from `rust/`.
fn baseline_path(file: &str) -> Option<PathBuf> {
    let cwd = std::env::current_dir().ok()?;
    cwd.ancestors().map(|d| d.join(file)).find(|p| p.is_file())
}

/// Print the geomean current/committed ratio per layout column against the
/// committed baseline snapshot (rows matched on op+shape+pattern+sparsity).
fn print_baseline_delta(rows: &[KernelRow]) {
    let Some(path) = baseline_path("BENCH_kernels.json") else {
        println!("baseline: no committed BENCH_kernels.json found (delta skipped)");
        return;
    };
    let parsed = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let Some(doc) = parsed else {
        println!("baseline: {} is unreadable (delta skipped)", path.display());
        return;
    };
    let mut base: std::collections::BTreeMap<String, Vec<(&str, f64)>> = Default::default();
    for row in doc.get("results").and_then(Json::as_arr).map(Vec::as_slice).unwrap_or(&[]) {
        let key = |f: &str| row.get(f).and_then(Json::as_str).unwrap_or("").to_string();
        let id = format!(
            "{}|{}|{}|{:.4}",
            key("op"),
            key("shape"),
            row.get("pattern").and_then(Json::as_str).unwrap_or("unstructured"),
            row.get("sparsity").and_then(Json::as_f64).unwrap_or(-1.0),
        );
        let mut cols = Vec::new();
        for c in ["dense_ns", "masked_ns", "csr_ns", "bsr_ns"] {
            if let Some(v) = row.get(c).and_then(Json::as_f64) {
                cols.push((c, v));
            }
        }
        base.insert(id, cols);
    }
    let mut ratios: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for r in rows {
        let id = format!("{}|{}|{}|{:.4}", r.op, r.shape, r.pattern, r.sparsity);
        let Some(cols) = base.get(&id) else { continue };
        for &(c, b) in cols {
            let cur = match c {
                "dense_ns" => r.dense_ns,
                "masked_ns" => r.masked_ns,
                "csr_ns" => r.csr_ns,
                _ => r.bsr_ns,
            };
            if b > 0.0 && cur > 0.0 {
                ratios.entry(c).or_default().push(cur / b);
            }
        }
    }
    if ratios.is_empty() {
        println!("baseline: no comparable rows in {} (delta skipped)", path.display());
        return;
    }
    let geomean = |v: &[f64]| (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp();
    let deltas: Vec<String> = ratios
        .iter()
        .map(|(c, v)| format!("{} {:.2}x", c.trim_end_matches("_ns"), geomean(v)))
        .collect();
    println!(
        "baseline delta vs {} (current/committed, geomean; <1.00 is faster): {}",
        path.display(),
        deltas.join(", ")
    );
}

/// `repro bench-kernels` — A/B the three weight layouts over the
/// runtime_micro GEMM shapes at pinned sparsity levels and record the
/// machine-readable trajectory in `results/bench_kernels.json`, so the
/// perf claims are tracked across PRs instead of eyeballed.
fn bench_kernels(args: &Args) -> Result<()> {
    use perp::tensor::sparse::{self, BsrMatrix, CsrMatrix, QuantBsr, QuantCsr, QuantKind};
    use perp::tensor::{linalg, Tensor};
    use perp::util::bench::{fmt_duration, Bench, Table};
    use perp::util::rng::Rng;
    use std::time::Duration;

    perp::util::threads::configure(args.opt_usize("threads")?);
    let out_dir = PathBuf::from(args.str("out", "results"));
    let shapes: Vec<(usize, usize, usize)> = args
        .list("shapes", "256x256x256,512x512x512,1024x256x1024")
        .iter()
        .map(|s| {
            let dims: Vec<usize> = s.split('x').filter_map(|d| d.parse().ok()).collect();
            match dims[..] {
                [n, k, m] if n * k * m > 0 => Ok((n, k, m)),
                _ => Err(ArgError(format!("--shapes expects NxKxM entries, got {s:?}"))),
            }
        })
        .collect::<Result<_, _>>()?;
    let sparsities: Vec<f64> = args
        .list("sparsities", "0.5,0.7,0.9,0.95,0.99")
        .iter()
        .map(|s| {
            s.parse::<f64>().ok().filter(|f| (0.0..=1.0).contains(f)).ok_or_else(|| {
                ArgError(format!("--sparsities expects fractions in [0,1], got {s:?}"))
            })
        })
        .collect::<Result<_, _>>()?;
    args.finish()?;

    let bench = Bench::quick();
    let ns = |d: Duration| d.as_secs_f64() * 1e9;
    let mut rows: Vec<KernelRow> = Vec::new();
    let mut rng = Rng::new(42);
    for &(n, k, m) in &shapes {
        let x = Tensor::randn(&[n, k], 1.0, &mut rng);
        let dy = Tensor::randn(&[n, m], 1.0, &mut rng);
        let w_nt = Tensor::randn(&[m, k], 1.0, &mut rng); // forward layout (out, in)
        let w_nn = Tensor::randn(&[m, k], 1.0, &mut rng); // backward-dx operand (m, k)

        // unstructured masks at every requested sparsity, plus the 2:4
        // semi-structured point (50%) whenever the inner dim allows it —
        // that row is where BSR's dense 1x4 tiles must beat CSR
        let mut cases: Vec<(&'static str, f64, Tensor)> = sparsities
            .iter()
            .map(|&s| ("unstructured", s, sparse::random_mask(&[m, k], s, &mut rng)))
            .collect();
        if k % 4 == 0 {
            cases.push(("2:4", 0.5, perp::pruning::semistructured::nm_mask(&w_nt, 2, 4)));
        }
        for (pattern, s, mask) in &cases {
            let (pattern, s) = (*pattern, *s);
            let structured = pattern == "2:4";
            let (br, bc) = BsrMatrix::native_block(structured);
            let shape_fwd = format!("{n}x{k} @ ({m}x{k})T");
            let shape_bwd = format!("{n}x{m} @ {m}x{k}");

            // forward: x @ (W⊙M)ᵀ
            let wm = w_nt.hadamard(mask);
            let csr = CsrMatrix::from_dense_masked(&w_nt, mask);
            let bsr = BsrMatrix::from_dense_masked(&w_nt, mask, br, bc);
            let qc16 = QuantCsr::from_csr(&csr, QuantKind::F16);
            let qc8 = QuantCsr::from_csr(&csr, QuantKind::I8);
            let qb16 = QuantBsr::from_bsr(&bsr, QuantKind::F16);
            let qb8 = QuantBsr::from_bsr(&bsr, QuantKind::I8);
            let d = bench.run(|| {
                std::hint::black_box(linalg::matmul_nt(&x, &wm));
            });
            let mk = bench.run(|| {
                std::hint::black_box(linalg::matmul_nt_masked(&x, &w_nt, mask));
            });
            let c = bench.run(|| {
                std::hint::black_box(sparse::spmm_nt(&x, &csr));
            });
            let b = bench.run(|| {
                std::hint::black_box(bsr.spmm_nt(&x));
            });
            let c16 = bench.run(|| {
                std::hint::black_box(qc16.spmm_nt(&x));
            });
            let c8 = bench.run(|| {
                std::hint::black_box(qc8.spmm_nt(&x));
            });
            let b16 = bench.run(|| {
                std::hint::black_box(qb16.spmm_nt(&x));
            });
            let b8 = bench.run(|| {
                std::hint::black_box(qb8.spmm_nt(&x));
            });
            rows.push(KernelRow {
                op: "forward",
                shape: shape_fwd,
                pattern,
                sparsity: s,
                dense_ns: ns(d.mean),
                masked_ns: ns(mk.mean),
                csr_ns: ns(c.mean),
                bsr_ns: ns(b.mean),
                csr_f16_ns: Some(ns(c16.mean)),
                csr_q8_ns: Some(ns(c8.mean)),
                bsr_f16_ns: Some(ns(b16.mean)),
                bsr_q8_ns: Some(ns(b8.mean)),
                bytes: Some(ValueBytes {
                    csr: csr.value_bytes(),
                    bsr: bsr.value_bytes(),
                    csr_q8: qc8.value_bytes(),
                    bsr_q8: qb8.value_bytes(),
                }),
            });

            // backward dx: dy @ (W⊙M) — exact layouts only (no quantised
            // backward by design)
            let wm_b = w_nn.hadamard(mask);
            let csr_b = CsrMatrix::from_dense_masked(&w_nn, mask);
            let bsr_b = BsrMatrix::from_dense_masked(&w_nn, mask, br, bc);
            let d = bench.run(|| {
                std::hint::black_box(linalg::matmul(&dy, &wm_b));
            });
            let mk = bench.run(|| {
                std::hint::black_box(linalg::matmul_masked(&dy, &w_nn, mask));
            });
            let c = bench.run(|| {
                std::hint::black_box(sparse::spmm(&dy, &csr_b));
            });
            let b = bench.run(|| {
                std::hint::black_box(bsr_b.spmm(&dy));
            });
            rows.push(KernelRow {
                op: "backward_dx",
                shape: shape_bwd,
                pattern,
                sparsity: s,
                dense_ns: ns(d.mean),
                masked_ns: ns(mk.mean),
                csr_ns: ns(c.mean),
                bsr_ns: ns(b.mean),
                csr_f16_ns: None,
                csr_q8_ns: None,
                bsr_f16_ns: None,
                bsr_q8_ns: None,
                bytes: None,
            });
        }
    }

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut t = Table::new(
        &format!("matmul layouts: dense vs masked vs CSR vs BSR vs quantised ({cores} cores)"),
        &[
            "op", "shape", "pattern", "sparsity", "dense", "masked", "csr", "bsr", "csr-q8",
            "bsr/csr", "csr/masked",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.op.to_string(),
            r.shape.clone(),
            r.pattern.to_string(),
            format!("{:.0}%", r.sparsity * 100.0),
            fmt_duration(Duration::from_nanos(r.dense_ns as u64)),
            fmt_duration(Duration::from_nanos(r.masked_ns as u64)),
            fmt_duration(Duration::from_nanos(r.csr_ns as u64)),
            fmt_duration(Duration::from_nanos(r.bsr_ns as u64)),
            r.csr_q8_ns
                .map(|v| fmt_duration(Duration::from_nanos(v as u64)))
                .unwrap_or_else(|| "-".to_string()),
            format!("{:.2}x", r.bsr_vs_csr()),
            format!("{:.2}x", r.vs_masked()),
        ]);
    }
    t.print();
    print_baseline_delta(&rows);

    let results = Json::Arr(
        rows.iter()
            .map(|r| {
                let mut fields = vec![
                    ("op", Json::Str(r.op.to_string())),
                    ("shape", Json::Str(r.shape.clone())),
                    ("pattern", Json::Str(r.pattern.to_string())),
                    ("sparsity", Json::Num(r.sparsity)),
                    ("dense_ns", Json::Num(r.dense_ns)),
                    ("masked_ns", Json::Num(r.masked_ns)),
                    ("csr_ns", Json::Num(r.csr_ns)),
                    ("bsr_ns", Json::Num(r.bsr_ns)),
                    ("csr_speedup_vs_masked", Json::Num(r.vs_masked())),
                    ("csr_speedup_vs_dense", Json::Num(r.vs_dense())),
                    ("bsr_speedup_vs_csr", Json::Num(r.bsr_vs_csr())),
                ];
                for (name, v) in [
                    ("csr_f16_ns", r.csr_f16_ns),
                    ("csr_q8_ns", r.csr_q8_ns),
                    ("bsr_f16_ns", r.bsr_f16_ns),
                    ("bsr_q8_ns", r.bsr_q8_ns),
                ] {
                    if let Some(v) = v {
                        fields.push((name, Json::Num(v)));
                    }
                }
                if let Some(vb) = &r.bytes {
                    fields.push(("csr_value_bytes", Json::Num(vb.csr as f64)));
                    fields.push(("bsr_value_bytes", Json::Num(vb.bsr as f64)));
                    fields.push(("csr_q8_value_bytes", Json::Num(vb.csr_q8 as f64)));
                    fields.push(("bsr_q8_value_bytes", Json::Num(vb.bsr_q8 as f64)));
                    fields.push((
                        "csr_q8_value_byte_ratio",
                        Json::Num(vb.csr_q8 as f64 / (vb.csr as f64).max(1.0)),
                    ));
                }
                Json::obj(fields)
            })
            .collect(),
    );

    // measured crossover table: per (pattern, sparsity), which layout had
    // the lowest summed time across shapes.  best_exact ranks the bitwise
    // layouts on forward+backward (the training path); best_any ranks all
    // layouts on forward only (the decode/eval path where quantised forms
    // are admissible).  `--layout auto` consumes this via
    // PERP_CROSSOVER_TABLE (set by `common()` when the file exists).
    #[derive(Default)]
    struct CrossAgg {
        fwd: std::collections::BTreeMap<&'static str, f64>,
        bwd: std::collections::BTreeMap<&'static str, f64>,
    }
    let mut agg: std::collections::BTreeMap<(&'static str, u64), CrossAgg> = Default::default();
    for r in &rows {
        let e = agg.entry((r.pattern, r.sparsity.to_bits())).or_default();
        let tgt = if r.op == "forward" { &mut e.fwd } else { &mut e.bwd };
        *tgt.entry("dense").or_default() += r.dense_ns;
        *tgt.entry("masked").or_default() += r.masked_ns;
        *tgt.entry("csr").or_default() += r.csr_ns;
        *tgt.entry("bsr").or_default() += r.bsr_ns;
        for (name, v) in [
            ("csr-f16", r.csr_f16_ns),
            ("csr-q8", r.csr_q8_ns),
            ("bsr-f16", r.bsr_f16_ns),
            ("bsr-q8", r.bsr_q8_ns),
        ] {
            if let Some(v) = v {
                *tgt.entry(name).or_default() += v;
            }
        }
    }
    const EXACT: [&str; 4] = ["dense", "masked", "csr", "bsr"];
    const ALL: [&str; 8] = [
        "dense", "masked", "csr", "bsr", "csr-f16", "csr-q8", "bsr-f16", "bsr-q8",
    ];
    let crossover: Vec<Json> = agg
        .iter()
        .map(|((pattern, sbits), a)| {
            let total = |l: &str| {
                a.fwd.get(l).copied().unwrap_or(f64::INFINITY)
                    + a.bwd.get(l).copied().unwrap_or(0.0)
            };
            let fwd_only = |l: &str| a.fwd.get(l).copied().unwrap_or(f64::INFINITY);
            let argmin = |cands: &[&'static str], f: &dyn Fn(&str) -> f64| {
                cands
                    .iter()
                    .copied()
                    .min_by(|x, y| f(x).partial_cmp(&f(y)).unwrap())
                    .unwrap()
            };
            Json::obj(vec![
                ("sparsity", Json::Num(f64::from_bits(*sbits))),
                ("pattern", Json::Str(pattern.to_string())),
                ("best_exact", Json::Str(argmin(&EXACT, &total).to_string())),
                ("best_any", Json::Str(argmin(&ALL, &fwd_only).to_string())),
            ])
        })
        .collect();

    let report = Json::obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        ("cores", Json::Num(cores as f64)),
        (
            "csr_crossover",
            Json::Num(perp::tensor::sparse::LayoutPolicy::csr_crossover()),
        ),
        ("crossover", Json::Arr(crossover)),
        ("results", results),
    ]);
    std::fs::create_dir_all(&out_dir).ok();
    let path = out_dir.join("bench_kernels.json");
    std::fs::write(&path, report.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {path:?}");
    Ok(())
}

// ---------------------------------------------------------------------------
// Plan-graph scheduler benchmark: serial vs parallel wall-clock.
// ---------------------------------------------------------------------------

/// `repro bench-graph` — time representative multi-fork sweep graphs with
/// `--jobs 1` vs `--jobs N` on a scratch stage cache and record the
/// trajectory in `results/bench_graph.json`, so the scheduler win is a
/// tracked number across PRs instead of eyeballed.  Dense checkpoints are
/// warmed untimed (both phases share the keyed dense cache); every timed
/// run starts from a wiped plan cache so it computes all nodes.
fn bench_graph(args: &Args) -> Result<()> {
    use perp::pipeline::GraphBuilder;
    use perp::util::bench::Table;

    let env = common(args)?;
    args.finish()?;
    let budget = perp::util::threads::budget();
    // a meaningful A/B needs ≥ 2 workers: --jobs/PERP_JOBS wins, otherwise
    // one worker per budget thread (min 2 even on a single-core box)
    let jobs = if env.jobs > 1 { env.jobs } else { budget.max(2) };

    let sweeps: Vec<(&str, perp::pipeline::PlanGraph)> = vec![
        (
            "sparsity_fan",
            GraphBuilder::new("sparsity_fan")
                .pretrain()
                .fork_sparsities(Criterion::Magnitude, &[0.5, 0.7, 0.9])
                .eval_ppl()
                .build(),
        ),
        (
            "seeded_prune",
            GraphBuilder::new("seeded_prune")
                .pretrain()
                .prune(Criterion::Magnitude, Pattern::Unstructured(0.6))
                .eval_ppl()
                .replicate_seeds(2)
                .aggregate("mean")
                .build(),
        ),
    ];

    let cache = env.out.join("cache-bench-graph");
    let plan_cache = cache.join("plan");
    let warm = ExpContext::new(env.rt.as_ref(), env.cfg.clone(), cache.clone());
    for seed in [env.seed, env.seed.wrapping_add(1)] {
        warm.dense_session(seed)?;
    }

    struct Row {
        sweep: String,
        nodes: usize,
        serial_s: f64,
        parallel_s: f64,
    }
    impl Row {
        fn speedup(&self) -> f64 {
            self.serial_s / self.parallel_s.max(1e-9)
        }
    }
    let mut rows: Vec<Row> = Vec::new();
    for (name, g) in &sweeps {
        let time_run = |jobs: usize| -> Result<f64> {
            std::fs::remove_dir_all(&plan_cache).ok();
            let ex = Executor::new(env.rt.as_ref(), env.cfg.clone(), cache.clone(), env.seed)
                .quiet(true)
                .jobs(jobs);
            let t0 = Instant::now();
            let report = ex.run_graph(g)?;
            anyhow::ensure!(
                report.computed() == g.stage_count(),
                "bench run must compute every node ({} of {} computed)",
                report.computed(),
                g.stage_count()
            );
            Ok(t0.elapsed().as_secs_f64())
        };
        let serial_s = time_run(1)?;
        let parallel_s = time_run(jobs)?;
        perp::util::logging::progress(&format!(
            "[bench-graph] {name}: serial {serial_s:.2}s, parallel {parallel_s:.2}s ({jobs} jobs)"
        ));
        rows.push(Row {
            sweep: name.to_string(),
            nodes: g.stage_count(),
            serial_s,
            parallel_s,
        });
    }
    std::fs::remove_dir_all(&cache).ok();

    let mut t = Table::new(
        &format!("plan-graph scheduler: serial vs {jobs} jobs ({budget} kernel threads)"),
        &["sweep", "nodes", "serial", "parallel", "speedup"],
    );
    for r in &rows {
        t.row(vec![
            r.sweep.clone(),
            format!("{}", r.nodes),
            format!("{:.2}s", r.serial_s),
            format!("{:.2}s", r.parallel_s),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    t.print();

    let results = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("sweep", Json::Str(r.sweep.clone())),
                    ("nodes", Json::Num(r.nodes as f64)),
                    ("serial_s", Json::Num(r.serial_s)),
                    ("parallel_s", Json::Num(r.parallel_s)),
                    ("speedup", Json::Num(r.speedup())),
                ])
            })
            .collect(),
    );
    let report = Json::obj(vec![
        ("bench", Json::Str("graph".to_string())),
        ("model", Json::Str(env.cfg.model.clone())),
        ("jobs", Json::Num(jobs as f64)),
        ("threads_budget", Json::Num(budget as f64)),
        ("results", results),
    ]);
    let path = env.out.join("bench_graph.json");
    std::fs::write(&path, report.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {path:?}");
    Ok(())
}

fn bench_serve(args: &Args) -> Result<()> {
    let env = common(args)?;
    let requests = args.usize("requests", 16)?.max(1);
    let max_tokens = args.usize("max-tokens", 16)?.max(1);
    let concurrency = args.usize("concurrency", 8)?.max(2);
    let from = args.opt_str("from").map(PathBuf::from);
    args.finish()?;

    let cache_dir = env.out.join("cache");
    if from.is_none() {
        // converge/cached once so both engines boot from the same weights
        ctx(&env).dense_session(env.seed)?;
    }
    let state = Arc::new(ServeState::new(
        "batched".to_string(),
        env.cfg.clone(),
        cache_dir.clone(),
        env.seed,
    ));
    for (name, max_active) in [("seq", 1usize), ("batched", usize::MAX)] {
        let handle = batcher::spawn(EngineSpec {
            name: name.to_string(),
            cfg: env.cfg.clone(),
            seed: env.seed,
            checkpoint: from.clone(),
            cache_dir: cache_dir.clone(),
            batch: BatchCfg {
                max_active,
                max_new_default: max_tokens,
                min_tokens: 1,
            },
            draft: None,
            spec_k: 0,
        })?;
        state.insert(handle)?;
    }
    let server = Server::bind(state, "127.0.0.1:0", concurrency + 2)?;
    let addr = server.addr;
    let handle = server.spawn();

    println!(
        "bench-serve: {} requests x {} tokens on {addr} (layout {})",
        requests, max_tokens, env.cfg.layout
    );
    let seq = bench_phase(addr, "seq", requests, 1, max_tokens)?;
    let bat = bench_phase(addr, "batched", requests, concurrency, max_tokens)?;
    handle.stop();

    let speedup = bat.tps / seq.tps.max(1e-9);
    let mut t = perp::util::bench::Table::new(
        &format!("serve decode throughput ({}, {requests} reqs)", env.cfg.model),
        &["phase", "clients", "tokens", "wall", "tok/s", "p50", "p95"],
    );
    for (name, clients, p) in [("sequential", 1, &seq), ("batched", concurrency, &bat)] {
        t.row(vec![
            name.to_string(),
            format!("{clients}"),
            format!("{}", p.tokens),
            format!("{:.2}s", p.wall_s),
            format!("{:.1}", p.tps),
            format!("{:.1}ms", p.p50_ms),
            format!("{:.1}ms", p.p95_ms),
        ]);
    }
    t.print();
    println!("batched/sequential speedup: {speedup:.2}x");

    let phase_json = |p: &PhaseStats| {
        Json::obj(vec![
            ("tokens", Json::Num(p.tokens as f64)),
            ("wall_s", Json::Num(p.wall_s)),
            ("tokens_per_s", Json::Num(p.tps)),
            ("latency_mean_ms", Json::Num(p.mean_ms)),
            ("latency_p50_ms", Json::Num(p.p50_ms)),
            ("latency_p95_ms", Json::Num(p.p95_ms)),
        ])
    };
    let report = Json::obj(vec![
        ("bench", Json::Str("serve".to_string())),
        ("model", Json::Str(env.cfg.model.clone())),
        ("layout", Json::Str(env.cfg.layout.clone())),
        ("requests", Json::Num(requests as f64)),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("concurrency", Json::Num(concurrency as f64)),
        ("sequential", phase_json(&seq)),
        ("batched", phase_json(&bat)),
        ("speedup", Json::Num(speedup)),
    ]);
    let path = env.out.join("bench_serve.json");
    std::fs::write(&path, report.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {path:?}");
    Ok(())
}

/// `repro bench-spec`: sequential decode vs speculative decode across a
/// grid of (draft sparsity × K).  Drafts are manufactured on the spot with
/// the paper's own recipe — magnitude prune, short MaskLoRA retrain, merge —
/// then each (draft, K) cell serves the same greedy `/generate` load as the
/// target-only baseline.  Acceptance statistics come from the engines'
/// `perp_obs_spec_*` metric families.
fn bench_spec(args: &Args) -> Result<()> {
    use perp::util::bench::Table;

    let env = common(args)?;
    let requests = args.usize("requests", 8)?.max(1);
    let max_tokens = args.usize("max-tokens", 24)?.max(1);
    let sparsities: Vec<f64> = args
        .str("sparsities", "0.5,0.9")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<f64>().map_err(|_| anyhow::anyhow!("bad sparsity {s:?}")))
        .collect::<Result<_>>()?;
    let ks: Vec<usize> = args
        .str("ks", "2,4")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad K {s:?}")))
        .collect::<Result<_>>()?;
    let retrain_steps = args
        .opt_usize("retrain-steps")?
        .map(|s| s as u64)
        .unwrap_or(env.cfg.retrain_steps);
    args.finish()?;
    anyhow::ensure!(!sparsities.is_empty() && !ks.is_empty(), "empty sparsity/K grid");
    let sw = env.rt.model(&env.cfg.model)?.cfg.spec_width;
    for &k in &ks {
        anyhow::ensure!(
            k >= 1 && k < sw,
            "K={k} outside [1, {}] (spec_width {sw})",
            sw - 1
        );
    }

    // -- manufacture drafts: prune -> masklora retrain -> merge -> save ----
    let cache_dir = env.out.join("cache");
    let cx = ctx(&env);
    cx.dense_session(env.seed)?; // converge/cache once; engines boot from it
    let lr = env.cfg.lr_grid.first().copied().unwrap_or(1e-3);
    let draft_dir = env.out.join("bench_spec_drafts");
    std::fs::create_dir_all(&draft_dir)?;
    let mut drafts: Vec<(f64, PathBuf)> = Vec::new();
    for &sp in &sparsities {
        let path = draft_dir.join(format!("draft_s{:03}.ptns", (sp * 1000.0).round() as u32));
        perp::util::logging::progress(&format!(
            "[bench-spec] draft @ {sp:.2}: magnitude prune + masklora x{retrain_steps} + merge"
        ));
        let (mut s, _dense) =
            cx.pruned_session(env.seed, Criterion::Magnitude, Pattern::Unstructured(sp))?;
        s.retrain(Mode::MaskLora, retrain_steps, lr)?;
        s.merge_adapters()?;
        s.save(&path)?;
        drafts.push((sp, path));
    }

    // -- one server, one engine per cell plus the target-only baseline -----
    let state = Arc::new(ServeState::new(
        "target".to_string(),
        env.cfg.clone(),
        cache_dir.clone(),
        env.seed,
    ));
    let batch = BatchCfg { max_active: 1, max_new_default: max_tokens, min_tokens: 1 };
    let mut cells: Vec<(f64, usize, String)> = Vec::new();
    let mut engine_specs = vec![EngineSpec {
        name: "target".to_string(),
        cfg: env.cfg.clone(),
        seed: env.seed,
        checkpoint: None,
        cache_dir: cache_dir.clone(),
        batch: batch.clone(),
        draft: None,
        spec_k: 0,
    }];
    for &(sp, ref path) in &drafts {
        for &k in &ks {
            let name = format!("spec-s{:03}-k{k}", (sp * 1000.0).round() as u32);
            cells.push((sp, k, name.clone()));
            engine_specs.push(EngineSpec {
                name,
                cfg: env.cfg.clone(),
                seed: env.seed,
                checkpoint: None,
                cache_dir: cache_dir.clone(),
                batch: batch.clone(),
                draft: Some(path.clone()),
                spec_k: k,
            });
        }
    }
    for spec in engine_specs {
        state.insert(batcher::spawn(spec)?)?;
    }
    let server = Server::bind(state, "127.0.0.1:0", 4)?;
    let addr = server.addr;
    let handle = server.spawn();

    println!(
        "bench-spec: {requests} requests x {max_tokens} tokens on {addr} \
         (sparsities {sparsities:?}, K {ks:?})"
    );
    let base = bench_phase(addr, "target", requests, 1, max_tokens)?;
    let mut phases: Vec<PhaseStats> = Vec::new();
    for (_, _, name) in &cells {
        phases.push(bench_phase(addr, name, requests, 1, max_tokens)?);
    }
    let (status, metrics) = client::get(addr, "/metrics")?;
    anyhow::ensure!(status == 200, "GET /metrics failed ({status})");
    handle.stop();

    // `perp_obs_spec_<family>_total{model="<name>"} <value>`
    let counter = |family: &str, model: &str| -> u64 {
        let needle = format!("perp_obs_spec_{family}_total{{model=\"{model}\"}}");
        metrics
            .lines()
            .find_map(|l| l.strip_prefix(needle.as_str()))
            .and_then(|rest| rest.trim().parse().ok())
            .unwrap_or(0)
    };

    let mut t = Table::new(
        &format!("speculative vs sequential decode ({}, {requests} reqs)", env.cfg.model),
        &["cell", "tok/s", "speedup", "accept", "rounds", "proposed"],
    );
    t.row(vec![
        "target".to_string(),
        format!("{:.1}", base.tps),
        "1.00x".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    let mut rows = Vec::new();
    for ((sp, k, name), p) in cells.iter().zip(&phases) {
        let (rounds, proposed, accepted) =
            (counter("rounds", name), counter("proposed", name), counter("accepted", name));
        let acceptance = accepted as f64 / proposed.max(1) as f64;
        let speedup = p.tps / base.tps.max(1e-9);
        t.row(vec![
            format!("s={sp:.2} K={k}"),
            format!("{:.1}", p.tps),
            format!("{speedup:.2}x"),
            format!("{:.0}%", acceptance * 100.0),
            format!("{rounds}"),
            format!("{proposed}"),
        ]);
        rows.push(Json::obj(vec![
            ("sparsity", Json::Num(*sp)),
            ("k", Json::Num(*k as f64)),
            ("tokens_per_s", Json::Num(p.tps)),
            ("speedup", Json::Num(speedup)),
            ("acceptance", Json::Num(acceptance)),
            ("rounds", Json::Num(rounds as f64)),
            ("proposed", Json::Num(proposed as f64)),
            ("accepted", Json::Num(accepted as f64)),
        ]));
    }
    t.print();

    let report = Json::obj(vec![
        ("bench", Json::Str("spec".to_string())),
        ("model", Json::Str(env.cfg.model.clone())),
        ("layout", Json::Str(env.cfg.layout.clone())),
        ("requests", Json::Num(requests as f64)),
        ("max_tokens", Json::Num(max_tokens as f64)),
        ("retrain_steps", Json::Num(retrain_steps as f64)),
        (
            "target",
            Json::obj(vec![
                ("tokens", Json::Num(base.tokens as f64)),
                ("wall_s", Json::Num(base.wall_s)),
                ("tokens_per_s", Json::Num(base.tps)),
            ]),
        ),
        ("cells", Json::Arr(rows)),
    ]);
    let path = env.out.join("bench_spec.json");
    std::fs::write(&path, report.to_string()).with_context(|| format!("writing {path:?}"))?;
    println!("wrote {path:?}");
    Ok(())
}
