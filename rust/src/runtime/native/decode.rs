//! Serving executables: KV-cache `prefill` and single-token `decode_step`.
//!
//! `prefill` runs the ordinary padded forward pass over up to
//! `cfg.serve_slots` prompts, then extracts each layer's K/V head planes
//! from the tape together with the logits at every stream's last valid
//! prompt position.  `decode_step` advances the active streams by exactly
//! one token: it embeds the freshly sampled token at its stream position,
//! runs the per-layer linears over the *compacted* active rows (so a
//! batch=1 stream pays batch=1 cost) — the q/k/v projections fused into
//! one sparse-aware kernel call per layer — attends each stream's single query
//! against its cache rows plus the new K/V, and emits the next-token
//! logits together with the new K/V rows.  The server owns the cache
//! tensors and writes those rows in place — the backend stays stateless.
//!
//! Every arithmetic loop mirrors the full forward pass' accumulation order
//! (`graph::forward` / `ops::attention_fwd`), so greedy KV decoding is
//! bit-identical to re-running the growing context through `forward` —
//! pinned by `tests/decode_parity.rs` on dense and 50%-sparse gpt-nano.

use std::collections::BTreeMap;

use anyhow::Result;
use rayon::prelude::*;

use crate::runtime::manifest::ModelManifest;
use crate::runtime::Outputs;
use crate::tensor::sparse::{SparseForm, WeightLayout};
use crate::tensor::{linalg, pool, Tensor};

use super::graph::{self, GraphIn, ModeKind, SparseView};
use super::ops;

pub(super) fn prefill(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
) -> Result<Outputs> {
    let (params, masks) = super::gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (slots, s, toks) = super::tokens_in(i32s);
    let (_, lens) = i32s["lens"];
    let vocab = mm.cfg.vocab;
    crate::count!("decode.prefills");

    let tape = graph::forward(&gi, toks, slots, s);
    let (full_logits, kv) = tape.into_logits_and_kv();
    let mut lg = pool::zeroed(slots * vocab);
    for (b, &len) in lens.iter().enumerate() {
        let len = (len.max(0) as usize).min(s);
        if len == 0 {
            continue; // idle slot: zero logits, cache plane is garbage
        }
        let src = &full_logits.data()[(b * s + len - 1) * vocab..(b * s + len) * vocab];
        lg[b * vocab..(b + 1) * vocab].copy_from_slice(src);
    }
    pool::recycle(full_logits);

    let mut values = vec![("logits".to_string(), Tensor::new(&[slots, vocab], lg))];
    for (i, (k, v)) in kv.into_iter().enumerate() {
        values.push((format!("k::h{i}"), k));
        values.push((format!("v::h{i}"), v));
    }
    Ok(Outputs { values })
}

pub(super) fn decode_step(
    mm: &ModelManifest,
    f32s: &BTreeMap<&str, &Tensor>,
    i32s: &BTreeMap<&str, (&[usize], &[i32])>,
    sparse: SparseView,
) -> Result<Outputs> {
    let cfg = &mm.cfg;
    let (nh, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
    let (slots, seq, vocab) = (cfg.serve_slots, cfg.seq_len, cfg.vocab);
    let (params, masks) = super::gather_params(mm, f32s);
    let gi = GraphIn {
        mm,
        params: &params,
        masks: &masks,
        adapters: None,
        mode: ModeKind::Subset,
        sparse,
    };
    let (_, toks) = i32s["tokens"];
    let (_, pos) = i32s["pos"];

    // compact the active streams: row r of every intermediate below belongs
    // to stream `active[r]`, so idle slots cost nothing
    let active: Vec<usize> =
        (0..slots).filter(|&b| pos[b] >= 0 && (pos[b] as usize) < seq).collect();
    crate::count!("decode.steps");
    crate::count!("decode.active_rows", active.len() as u64);

    let mut out_logits = pool::zeroed(slots * vocab);
    let mut knew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * nh * dh)).collect();
    let mut vnew: Vec<Vec<f32>> =
        (0..cfg.n_layers).map(|_| pool::zeroed(slots * nh * dh)).collect();

    if !active.is_empty() {
        let na = active.len();
        // x = E[token] + P[pos], one row per active stream
        let embt = gi.p("embed_tokens");
        let post = gi.p("embed_pos");
        let mut x = pool::zeroed(na * d);
        for (r, &b) in active.iter().enumerate() {
            let tok = (toks[b].max(0) as usize).min(vocab - 1);
            let p = pos[b] as usize;
            let erow = &embt.data()[tok * d..(tok + 1) * d];
            let prow = &post.data()[p * d..(p + 1) * d];
            for j in 0..d {
                x[r * d + j] = erow[j] + prow[j];
            }
        }
        let mut cur = Tensor::new(&[na, d], x);

        for i in 0..cfg.n_layers {
            let pfx = format!("h{i}_");
            let h1 = norm_apply(&gi, &format!("{pfx}ln1"), &cur);
            let (q, k, v) = match fused_qkv(&gi, &pfx, &h1) {
                Some(heads) => heads,
                None => (
                    linear_apply(&gi, &format!("{pfx}attn_q"), &h1),
                    linear_apply(&gi, &format!("{pfx}attn_k"), &h1),
                    linear_apply(&gi, &format!("{pfx}attn_v"), &h1),
                ),
            };
            pool::recycle(h1);
            // the new K/V rows, head-major — both the cache-delta outputs
            // and this step's self-attention contribution
            for (r, &b) in active.iter().enumerate() {
                for hd in 0..nh {
                    let src = r * d + hd * dh;
                    let dst = b * nh * dh + hd * dh;
                    knew[i][dst..dst + dh].copy_from_slice(&k.data()[src..src + dh]);
                    vnew[i][dst..dst + dh].copy_from_slice(&v.data()[src..src + dh]);
                }
            }
            let kc = f32s[format!("k::h{i}").as_str()];
            let vc = f32s[format!("v::h{i}").as_str()];
            let merged = attend(&q, &k, &v, kc, vc, &active, pos, nh, dh, seq);
            pool::recycle(q);
            pool::recycle(k);
            pool::recycle(v);
            let o = linear_apply(&gi, &format!("{pfx}attn_o"), &merged);
            pool::recycle(merged);
            let res_mid = cur.add(&o);
            pool::recycle(cur);
            pool::recycle(o);
            let h2 = norm_apply(&gi, &format!("{pfx}ln2"), &res_mid);
            let fc = linear_apply(&gi, &format!("{pfx}mlp_fc"), &h2);
            pool::recycle(h2);
            let g = ops::gelu(&fc);
            pool::recycle(fc);
            let proj = linear_apply(&gi, &format!("{pfx}mlp_proj"), &g);
            pool::recycle(g);
            cur = res_mid.add(&proj);
            pool::recycle(res_mid);
            pool::recycle(proj);
        }

        let hf = norm_apply(&gi, "final_ln", &cur);
        pool::recycle(cur);
        let logits = linalg::matmul_nt(&hf, gi.p("head_w"));
        pool::recycle(hf);
        for (r, &b) in active.iter().enumerate() {
            out_logits[b * vocab..(b + 1) * vocab]
                .copy_from_slice(&logits.data()[r * vocab..(r + 1) * vocab]);
        }
        pool::recycle(logits);
    }

    let mut values = vec![("logits".to_string(), Tensor::new(&[slots, vocab], out_logits))];
    for (i, (kn, vn)) in knew.into_iter().zip(vnew).enumerate() {
        values.push((format!("knew::h{i}"), Tensor::new(&[slots, nh, dh], kn)));
        values.push((format!("vnew::h{i}"), Tensor::new(&[slots, nh, dh], vn)));
    }
    Ok(Outputs { values })
}

/// Norm forward without keeping the backward cache.
pub(super) fn norm_apply(gi: &GraphIn, prefix: &str, x: &Tensor) -> Tensor {
    let scale = gi.p(&format!("{prefix}_scale"));
    if gi.mm.cfg.norm == "layernorm" {
        let (y, cache) = ops::layernorm_fwd(x, scale, gi.p(&format!("{prefix}_bias")));
        cache.recycle();
        y
    } else {
        let (y, cache) = ops::rmsnorm_fwd(x, scale);
        cache.recycle();
        y
    }
}

/// Plain masked linear (the decode path always runs merged weights —
/// adapters are folded before serving), routed through the layout seam: at
/// serve-time sparsities the CSR form reads only surviving weights, which
/// is where the decode path's memory-traffic reduction comes from.
pub(super) fn linear_apply(gi: &GraphIn, base: &str, x: &Tensor) -> Tensor {
    let wname = format!("{base}_w");
    let mut y = graph::masked_fwd(gi, &wname, x);
    if gi.mm.cfg.use_bias {
        ops::add_bias(&mut y, gi.p(&format!("{base}_b")));
    }
    y
}

/// Output-column batch for the single-stream fused q/k/v dispatch — matches
/// the sparse kernels' own task granularity so one decode step still spreads
/// across the rayon pool.
const QKV_COLS_PER_TASK: usize = 64;

/// One head's column kernel inside the fused q/k/v pass: either a cached
/// compressed form or the inline masked dot, both producing the exact
/// per-output-element accumulation order of `linalg::matmul_nt_masked` /
/// `SparseForm::spmm_nt` so fusing never changes a bit.
enum HeadKernel<'a> {
    Form(&'a SparseForm),
    Masked { w: &'a [f32], m: &'a [f32] },
}

impl HeadKernel<'_> {
    fn dots_range(&self, arow: &[f32], j0: usize, out: &mut [f32]) {
        match self {
            HeadKernel::Form(f) => f.dots_range(arow, j0, out),
            HeadKernel::Masked { w, m } => {
                let k = arow.len();
                for (jj, o) in out.iter_mut().enumerate() {
                    let j = j0 + jj;
                    let wrow = &w[j * k..(j + 1) * k];
                    let mrow = &m[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for kk in 0..k {
                        if mrow[kk] == 0.0 {
                            continue; // pruned weight: skipped, not multiplied
                        }
                        acc += arow[kk] * wrow[kk];
                    }
                    *o = acc;
                }
            }
        }
    }
}

/// Walk a span of the combined `[q|k|v]` output row, dispatching each
/// maximal single-head run to that head's kernel.  `c0` is the span's start
/// in combined-column coordinates; `offs` are the head boundaries.
fn qkv_run_heads(
    kernels: &[HeadKernel; 3],
    offs: &[usize; 4],
    arow: &[f32],
    c0: usize,
    out: &mut [f32],
) {
    let mut done = 0;
    while done < out.len() {
        let c = c0 + done;
        let h = if c < offs[1] {
            0
        } else if c < offs[2] {
            1
        } else {
            2
        };
        let run = (offs[h + 1] - c).min(out.len() - done);
        kernels[h].dots_range(arow, c - offs[h], &mut out[done..done + run]);
        done += run;
    }
}

/// Fused q/k/v projection: one kernel call computes all three attention
/// heads' outputs in a single pass over each activation row, instead of the
/// three independent SpMM dispatches `linear_apply` would make.  With one
/// active stream (the common decode case) the combined `[q|k|v]` output row
/// is split across the rayon pool by column chunk; with several streams the
/// pass parallelises over rows, each task reading its activation row once
/// while filling all three head segments.  Returns `None` when any head is
/// routed `Dense` — that path wants the BLAS-shaped dense matmul, not a
/// per-column loop.  Bitwise-identical to the unfused path because every
/// head run reuses the same per-output-element kernels (`dots_range` /
/// the masked inner loop) the separate calls would hit.
pub(super) fn fused_qkv(gi: &GraphIn, pfx: &str, x: &Tensor) -> Option<(Tensor, Tensor, Tensor)> {
    let names = [
        format!("{pfx}attn_q_w"),
        format!("{pfx}attn_k_w"),
        format!("{pfx}attn_v_w"),
    ];
    let layouts = [
        gi.sparse.layout_of(&names[0]),
        gi.sparse.layout_of(&names[1]),
        gi.sparse.layout_of(&names[2]),
    ];
    if layouts.contains(&WeightLayout::Dense) {
        return None;
    }
    let kernels: [HeadKernel; 3] = [0usize, 1, 2].map(|i| match gi.sparse.get_form(&names[i]) {
        Some(f) => HeadKernel::Form(f),
        None => HeadKernel::Masked { w: gi.p(&names[i]).data(), m: gi.m(&names[i]).data() },
    });
    for l in layouts {
        graph::count_spmm(l);
    }
    crate::count!("decode.qkv_fused");

    let (na, d) = (x.rows(), x.cols());
    let (d0, d1, d2) = (
        gi.p(&names[0]).rows(),
        gi.p(&names[1]).rows(),
        gi.p(&names[2]).rows(),
    );
    let dtot = d0 + d1 + d2;
    let offs = [0, d0, d0 + d1, dtot];
    let xd = x.data();
    let mut out = pool::zeroed(na * dtot);
    if na == 1 {
        out.par_chunks_mut(QKV_COLS_PER_TASK).enumerate().for_each(|(ci, chunk)| {
            qkv_run_heads(&kernels, &offs, xd, ci * QKV_COLS_PER_TASK, chunk);
        });
    } else {
        out.par_chunks_mut(dtot).enumerate().for_each(|(r, orow)| {
            qkv_run_heads(&kernels, &offs, &xd[r * d..(r + 1) * d], 0, orow);
        });
    }

    let mut heads = Vec::with_capacity(3);
    for (h, &dh_out) in [d0, d1, d2].iter().enumerate() {
        let mut hd = pool::zeroed(na * dh_out);
        for r in 0..na {
            hd[r * dh_out..(r + 1) * dh_out]
                .copy_from_slice(&out[r * dtot + offs[h]..r * dtot + offs[h] + dh_out]);
        }
        let mut t = Tensor::new(&[na, dh_out], hd);
        if gi.mm.cfg.use_bias {
            let base = &names[h][..names[h].len() - 2]; // strip the "_w"
            ops::add_bias(&mut t, gi.p(&format!("{base}_b")));
        }
        heads.push(t);
    }
    pool::recycle(Tensor::new(&[na, dtot], out));
    let mut it = heads.into_iter();
    Some((it.next().unwrap(), it.next().unwrap(), it.next().unwrap()))
}

/// One query per active stream against its cache rows plus the freshly
/// computed K/V at position `pos[b]`.  Mirrors `ops::attention_fwd`'s
/// score/softmax/accumulation order exactly so KV decoding stays
/// bit-identical to the full forward pass.
#[allow(clippy::too_many_arguments)]
fn attend(
    q: &Tensor,
    knew: &Tensor,
    vnew: &Tensor,
    kc: &Tensor,
    vc: &Tensor,
    active: &[usize],
    pos: &[i32],
    nh: usize,
    dh: usize,
    seq: usize,
) -> Tensor {
    let na = active.len();
    let d = nh * dh;
    let scale = 1.0 / (dh as f32).sqrt();
    let mut out = pool::zeroed(na * d);
    let (qd, knd, vnd) = (q.data(), knew.data(), vnew.data());
    let (kcd, vcd) = (kc.data(), vc.data());
    out.par_chunks_mut(d).enumerate().for_each(|(r, orow)| {
        let b = active[r];
        let p = pos[b] as usize; // cached rows 0..p are valid; self at j == p
        for hd in 0..nh {
            let qv = &qd[r * d + hd * dh..r * d + (hd + 1) * dh];
            let newrow = r * d + hd * dh..r * d + (hd + 1) * dh;
            let cbase = b * nh * seq * dh + hd * seq * dh;
            let mut row = vec![0.0f32; p + 1];
            let mut mx = f32::NEG_INFINITY;
            for (j, rj) in row.iter_mut().enumerate() {
                let kj: &[f32] = if j < p {
                    &kcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    &knd[newrow.clone()]
                };
                let dot: f32 = qv.iter().zip(kj).map(|(&a, &c)| a * c).sum();
                *rj = dot * scale;
                mx = mx.max(*rj);
            }
            let mut denom = 0.0f32;
            for rj in row.iter_mut() {
                *rj = (*rj - mx).exp();
                denom += *rj;
            }
            let orow_h = &mut orow[hd * dh..(hd + 1) * dh];
            for (j, &rj) in row.iter().enumerate() {
                let pj = rj / denom;
                let vj: &[f32] = if j < p {
                    &vcd[cbase + j * dh..cbase + (j + 1) * dh]
                } else {
                    &vnd[newrow.clone()]
                };
                for (o, &vv) in orow_h.iter_mut().zip(vj) {
                    *o += pj * vv;
                }
            }
        }
    });
    Tensor::new(&[na, d], out)
}
