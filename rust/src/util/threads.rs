//! Kernel thread-pool sizing.
//!
//! The rayon global pool defaults to one thread per logical core — correct
//! for batch experiments, but the serving layer also runs HTTP workers and
//! per-model engine threads on the same host, and oversubscription turns
//! into tail latency.  `--threads <n>` (or `PERP_THREADS=<n>`) pins the
//! kernel pool size explicitly; call [`configure`] before the first rayon
//! use (the CLI does this while parsing common flags).

/// Size the global rayon pool: explicit argument wins, then
/// `PERP_THREADS`, otherwise rayon's default.  Returns the effective
/// thread count.  A second call (or a call after rayon was already used)
/// cannot resize the pool — it warns and reports the existing size.
pub fn configure(threads: Option<usize>) -> usize {
    let requested = threads.or_else(from_env);
    if let Some(n) = requested {
        let n = n.max(1);
        match rayon::ThreadPoolBuilder::new().num_threads(n).build_global() {
            Ok(()) => crate::debug!("rayon pool sized to {n} threads"),
            Err(e) => {
                if rayon::current_num_threads() != n {
                    crate::warn!(
                        "rayon pool already initialised with {} threads ({e}); \
                         --threads/PERP_THREADS ignored",
                        rayon::current_num_threads()
                    );
                }
            }
        }
    }
    rayon::current_num_threads()
}

/// Parse `PERP_THREADS` (ignored when unset, empty or non-numeric).
pub fn from_env() -> Option<usize> {
    std::env::var("PERP_THREADS").ok().and_then(|v| v.trim().parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configure_reports_a_live_pool() {
        // No explicit request: must not panic, and the pool has ≥ 1 thread.
        assert!(configure(None) >= 1);
        // A redundant explicit request after initialisation stays sane.
        let n = rayon::current_num_threads();
        assert_eq!(configure(Some(n)), n);
    }
}
