//! Tiny CLI argument parser (clap replacement).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`.
//! Typed accessors with defaults; malformed values surface as [`ArgError`]
//! (the launcher prints them as `argument error: ...` and exits 2, never a
//! panic backtrace); unknown-argument detection via [`Args::finish`].

use std::collections::BTreeMap;

/// A user-facing argument problem: bad value, unknown flag, stray
/// positional.  Distinct from runtime errors so `main` can exit 2.
#[derive(Debug, Clone, thiserror::Error)]
#[error("{0}")]
pub struct ArgError(pub String);

impl ArgError {
    fn bad(key: &str, want: &str, got: &str) -> ArgError {
        ArgError(format!("--{key} expects {want}, got {got:?}"))
    }
}

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    /// positionals after the subcommand (`repro plan show <file>`); any the
    /// dispatcher never reads surface as errors in [`Args::finish`]
    positionals: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    consumed_pos: std::cell::Cell<usize>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, ArgError> {
        let mut subcommand = None;
        let mut positionals = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(key.to_string());
                }
            } else if subcommand.is_none() {
                subcommand = Some(a.clone());
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            subcommand,
            positionals,
            opts,
            flags,
            consumed: Default::default(),
            consumed_pos: Default::default(),
        })
    }

    pub fn from_env() -> Result<Args, ArgError> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Parse-if-present core all the typed accessors share.
    fn parsed<T: std::str::FromStr>(&self, key: &str, want: &str) -> Result<Option<T>, ArgError> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(None),
            Some(v) => v.parse::<T>().map(Some).map_err(|_| ArgError::bad(key, want, v)),
        }
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>, ArgError> {
        self.parsed(key, "an integer")
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, ArgError> {
        Ok(self.parsed(key, "an integer")?.unwrap_or(default))
    }

    pub fn opt_u64(&self, key: &str) -> Result<Option<u64>, ArgError> {
        self.parsed(key, "an integer")
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64, ArgError> {
        Ok(self.parsed(key, "an integer")?.unwrap_or(default))
    }

    pub fn opt_f64(&self, key: &str) -> Result<Option<f64>, ArgError> {
        self.parsed(key, "a number")
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, ArgError> {
        Ok(self.parsed(key, "a number")?.unwrap_or(default))
    }

    /// `--jobs {auto|K}`: concurrent plan-graph workers.  `auto` resolves
    /// to the kernel thread budget; K must be a positive integer.
    pub fn opt_jobs(&self) -> Result<Option<crate::util::threads::Jobs>, ArgError> {
        self.parsed("jobs", "\"auto\" or a positive integer")
    }

    /// `--layout <policy>`: weight-layout selection policy.  An unknown
    /// layout name is an [`ArgError`] listing the allowed set (the launcher
    /// exits 2) — it must never fall through to a default.
    pub fn opt_layout(&self) -> Result<Option<crate::tensor::sparse::LayoutPolicy>, ArgError> {
        let want = format!("one of {}", crate::tensor::sparse::ALLOWED_LAYOUTS);
        self.parsed("layout", &want)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option.
    pub fn list(&self, key: &str, default: &str) -> Vec<String> {
        self.str(key, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// The `i`-th positional after the subcommand, if present.  Reading
    /// index `i` marks positions `0..=i` as consumed for [`Args::finish`].
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.consumed_pos.set(self.consumed_pos.get().max(i + 1));
        self.positionals.get(i).map(String::as_str)
    }

    /// Error on any option/flag/positional that no accessor ever looked at.
    pub fn finish(&self) -> Result<(), ArgError> {
        let seen = self.consumed.borrow();
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(ArgError(format!("unknown argument --{k}")));
            }
        }
        if self.positionals.len() > self.consumed_pos.get() {
            return Err(ArgError(format!(
                "unexpected positional argument {:?}",
                self.positionals[self.consumed_pos.get()]
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        let v: Vec<String> = s.split_whitespace().map(|x| x.to_string()).collect();
        Args::parse(&v).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = args("sweep --exp table1 --seed 3 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.str("exp", ""), "table1");
        assert_eq!(a.u64("seed", 0).unwrap(), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let a = args("run --lr=0.001 --steps=100");
        assert_eq!(a.f64("lr", 0.0).unwrap(), 0.001);
        assert_eq!(a.usize("steps", 0).unwrap(), 100);
    }

    #[test]
    fn list_option() {
        let a = args("x --models a,b,,c");
        assert_eq!(a.list("models", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn defaults() {
        let a = args("x");
        assert_eq!(a.str("missing", "d"), "d");
        assert_eq!(a.usize("n", 7).unwrap(), 7);
        assert_eq!(a.opt_u64("n").unwrap(), None);
        assert_eq!(a.opt_f64("n").unwrap(), None);
    }

    #[test]
    fn opt_usize_present_and_absent() {
        let a = args("serve --port 7070");
        assert_eq!(a.opt_usize("port").unwrap(), Some(7070));
        assert_eq!(a.opt_usize("threads").unwrap(), None);
        a.finish().unwrap();
    }

    #[test]
    fn malformed_values_error_instead_of_panicking() {
        let a = args("retrain --steps abc --lr fast --port 1.5");
        let e = a.u64("steps", 0).unwrap_err();
        assert!(e.to_string().contains("--steps"), "{e}");
        assert!(e.to_string().contains("abc"), "{e}");
        assert!(a.f64("lr", 0.0).is_err());
        assert!(a.usize("port", 0).is_err());
        assert!(a.opt_usize("port").is_err());
        // well-formed values still parse on the same Args
        let a = args("x --steps 12");
        assert_eq!(a.u64("steps", 0).unwrap(), 12);
    }

    #[test]
    fn jobs_accessor_is_typed() {
        use crate::util::threads::Jobs;
        let a = args("run --jobs auto");
        assert_eq!(a.opt_jobs().unwrap(), Some(Jobs::Auto));
        a.finish().unwrap();
        let a = args("run --jobs 4");
        assert_eq!(a.opt_jobs().unwrap(), Some(Jobs::Fixed(4)));
        let a = args("run");
        assert_eq!(a.opt_jobs().unwrap(), None);
        // zero, negatives and words surface as ArgError (exit 2), no panic
        for bad in ["run --jobs 0", "run --jobs -3", "run --jobs fast"] {
            let a = args(bad);
            let e = a.opt_jobs().unwrap_err();
            assert!(e.to_string().contains("--jobs"), "{e}");
        }
    }

    #[test]
    fn layout_accessor_rejects_unknown_with_allowed_set() {
        use crate::tensor::sparse::{LayoutPolicy, WeightLayout};
        let a = args("serve --layout bsr");
        assert_eq!(a.opt_layout().unwrap(), Some(LayoutPolicy::Fixed(WeightLayout::Bsr)));
        a.finish().unwrap();
        let a = args("serve --layout auto-q");
        assert_eq!(a.opt_layout().unwrap(), Some(LayoutPolicy::AutoQuant));
        let a = args("serve");
        assert_eq!(a.opt_layout().unwrap(), None);
        // unknown layouts are an ArgError (exit 2) naming the allowed set
        let a = args("serve --layout coo");
        let e = a.opt_layout().unwrap_err().to_string();
        assert!(e.contains("--layout"), "{e}");
        assert!(e.contains("coo"), "{e}");
        assert!(e.contains("bsr-q8"), "{e}");
    }

    #[test]
    fn unknown_args_detected() {
        let a = args("x --known 1 --unknown 2");
        let _ = a.usize("known", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn positionals_consumed_or_rejected() {
        // unread positionals surface at finish(), like unknown options
        let a = args("a b");
        assert!(a.finish().is_err());
        // read positionals are fine, and --flags around them still parse
        let a = args("plan show examples/plans/x.json --dot");
        assert_eq!(a.subcommand.as_deref(), Some("plan"));
        assert_eq!(a.pos(0), Some("show"));
        assert_eq!(a.pos(1), Some("examples/plans/x.json"));
        assert_eq!(a.pos(2), None);
        assert!(a.flag("dot"));
        a.finish().unwrap();
    }
}
