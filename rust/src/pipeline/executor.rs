//! The plan executor: drive a [`Plan`] over a [`Session`] with
//! content-addressed artifact caching.
//!
//! Every stage writes its outputs under `<cache>/plan/<key>/` where `key` is
//! the FNV chain of (model, config, seed, backend, all upstream stages):
//!
//! | stage       | artifacts                                         |
//! |-------------|---------------------------------------------------|
//! | pretrain    | `meta.json` (weights live in the shared dense checkpoint cache) |
//! | prune       | `state.ptns`, `masks.ptns`, `meta.json` (sparsity) |
//! | retrain     | `state.ptns`, `masks.ptns`, [`lora.ptns`], `meta.json` (tps, trainable%) |
//! | reconstruct | `state.ptns`, `masks.ptns`, `meta.json` (mean layer-loss drop) |
//! | merge       | `state.ptns`, `masks.ptns`, `meta.json`           |
//! | eval        | `metrics.json` (ppl, acc, per-task, sparsity)     |
//! | export      | none — always executes (side effect outside the cache) |
//!
//! `meta.json` / `metrics.json` are written last, so their presence marks a
//! complete stage; `.ptns` writes are temp-file + rename (see
//! [`crate::tensor::io`]), so a crashed run never leaves a half-artifact
//! that passes the completeness check.  Re-running a plan therefore loads
//! completed stages (zero training steps, zero backend executions) and only
//! computes the suffix that changed.  `force` ignores the stage cache; the
//! keyed dense pretrain checkpoint is still honoured because it is
//! deterministic in exactly the inputs the key hashes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::reconstruct;
use crate::coordinator::sweep::ExpContext;
use crate::coordinator::Session;
use crate::model::ParamStore;
use crate::peft::{LoraState, Mode};
use crate::pruning::MaskSet;
use crate::runtime::{Backend, ModelManifest};
use crate::tensor::{io, Tensor};
use crate::util::json::Json;

use super::cachekey::{base_key, Key};
use super::plan::{Plan, Stage};

/// What an `eval` stage measured.
#[derive(Debug, Clone)]
pub struct EvalMetrics {
    pub ppl: f64,
    pub loss: f64,
    /// mean zero-shot accuracy; NaN when the stage ran perplexity-only
    pub acc: f64,
    pub per_task: Vec<(String, f64)>,
    /// achieved weight sparsity at evaluation time
    pub sparsity: f64,
}

/// Outcome of one stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub label: String,
    /// 16-hex content address of this stage's artifacts
    pub key: String,
    pub cache_hit: bool,
    pub wall_s: f64,
    /// populated by `eval` stages
    pub metrics: Option<EvalMetrics>,
    /// populated by `prune` stages
    pub sparsity: Option<f64>,
    /// populated by `retrain` stages
    pub tps: Option<f64>,
    pub trainable_pct: Option<f64>,
    /// learning rate the retrain stage actually used (grid-tuned when the
    /// plan left it unpinned)
    pub lr: Option<f64>,
    /// populated by `reconstruct` stages
    pub mean_improvement: Option<f64>,
}

impl StageReport {
    fn new(label: String, key: &Key) -> StageReport {
        StageReport {
            label,
            key: key.hex(),
            cache_hit: false,
            wall_s: 0.0,
            metrics: None,
            sparsity: None,
            tps: None,
            trainable_pct: None,
            lr: None,
            mean_improvement: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunReport {
    pub plan: String,
    pub stages: Vec<StageReport>,
}

impl RunReport {
    pub fn cache_hits(&self) -> usize {
        self.stages.iter().filter(|s| s.cache_hit).count()
    }

    /// Metrics of the last `eval` stage, if any.
    pub fn last_metrics(&self) -> Option<&EvalMetrics> {
        self.stages.iter().rev().find_map(|s| s.metrics.as_ref())
    }

    /// All `eval` stage metrics in plan order.
    pub fn metrics(&self) -> Vec<&EvalMetrics> {
        self.stages.iter().filter_map(|s| s.metrics.as_ref()).collect()
    }

    pub fn summary(&self) -> String {
        format!(
            "plan {}: {}/{} stages from cache",
            self.plan,
            self.cache_hits(),
            self.stages.len()
        )
    }
}

/// Drives plans over sessions.  Construct once per (backend, config, seed);
/// run as many plans as you like — shared prefixes share artifacts.
pub struct Executor<'rt> {
    rt: &'rt dyn Backend,
    cfg: ExperimentConfig,
    /// results cache root (also holds the dense checkpoint cache)
    cache_dir: PathBuf,
    seed: u64,
    force: bool,
    quiet: bool,
}

impl<'rt> Executor<'rt> {
    pub fn new(
        rt: &'rt dyn Backend,
        cfg: ExperimentConfig,
        cache_dir: PathBuf,
        seed: u64,
    ) -> Executor<'rt> {
        Executor { rt, cfg, cache_dir, seed, force: false, quiet: false }
    }

    /// Ignore completed stage artifacts and recompute everything.
    pub fn force(mut self, force: bool) -> Self {
        self.force = force;
        self
    }

    /// Suppress per-stage progress lines (sweeps drive many small plans).
    pub fn quiet(mut self, quiet: bool) -> Self {
        self.quiet = quiet;
        self
    }

    pub fn run(&self, plan: &Plan) -> Result<RunReport> {
        self.run_with_session(plan).map(|(report, _)| report)
    }

    /// Run a plan, returning the report plus the final session state (the
    /// CLI shims print from it).
    pub fn run_with_session(&self, plan: &Plan) -> Result<(RunReport, Session<'rt>)> {
        plan.validate()
            .map_err(|e| anyhow::anyhow!("invalid plan {:?}: {e}", plan.name))?;
        let ctx = ExpContext::new(self.rt, self.cfg.clone(), self.cache_dir.clone());
        let total = plan.stages.len();
        let mut key = base_key(&self.cfg, self.seed);
        let mut session: Option<Session<'rt>> = None;
        // weights snapshotted just before the most recent prune — the
        // reconstruction targets (Eq. 1's dense W_l).  Only kept when a
        // later stage actually reconstructs; plans without one skip the copy
        let last_recon = plan
            .stages
            .iter()
            .rposition(|s| matches!(s, Stage::Reconstruct { .. }));
        let mut pre_prune: Option<BTreeMap<String, Tensor>> = None;
        let mut reports = Vec::with_capacity(total);

        for (i, stage) in plan.stages.iter().enumerate() {
            key = key.push(&stage.canonical());
            let dir = self.cache_dir.join("plan").join(key.hex());
            let t0 = Instant::now();
            let mut rep = StageReport::new(stage.label(), &key);

            match stage {
                Stage::Pretrain => {
                    rep.cache_hit = !self.force && dir.join("meta.json").is_file();
                    // dense_session loads the shared checkpoint when present,
                    // so even a cache-miss marker costs no training steps if
                    // an earlier run (or sweep) already converged this config
                    session = Some(ctx.dense_session(self.seed)?);
                    if !rep.cache_hit {
                        self.write_meta(&dir, stage, vec![])?;
                    }
                }
                Stage::Prune { criterion, pattern } => {
                    let mut s = session.take().expect("validated plan: session exists");
                    // snapshot the reconstruction targets from the incoming
                    // weights — correct on both the hit and miss path
                    if last_recon.is_some_and(|r| r > i) {
                        pre_prune = Some(
                            s.mm.prunable
                                .iter()
                                .map(|n| (n.clone(), s.params.get(n).clone()))
                                .collect(),
                        );
                    }
                    if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                        rep.cache_hit = true;
                        self.load_state(&mut s, &dir)?;
                        rep.sparsity = read_meta_num(&dir, "sparsity");
                    } else {
                        let grams = if criterion.needs_calibration() {
                            Some(s.calibrate()?)
                        } else {
                            None
                        };
                        s.prune(*criterion, *pattern, grams.as_ref())?;
                        let sparsity = s.masks.sparsity();
                        rep.sparsity = Some(sparsity);
                        self.save_state(&s, &dir)?;
                        self.write_meta(&dir, stage, vec![("sparsity", Json::Num(sparsity))])?;
                    }
                    session = Some(s);
                }
                Stage::Retrain { mode, steps, lr } => {
                    let steps = steps.unwrap_or(self.cfg.retrain_steps);
                    let mut needs = vec!["state.ptns", "masks.ptns"];
                    if mode.is_lora() {
                        needs.push("lora.ptns");
                    }
                    needs.push("meta.json");
                    if self.hit(&dir, &needs) {
                        rep.cache_hit = true;
                        let mut s = session.take().expect("validated plan: session exists");
                        self.load_state(&mut s, &dir)?;
                        s.lora = if mode.is_lora() {
                            Some((*mode, load_lora(&s.mm, &dir.join("lora.ptns"))?))
                        } else {
                            None
                        };
                        s.last_tps = read_meta_num(&dir, "tps").unwrap_or(0.0);
                        rep.tps = Some(s.last_tps);
                        rep.trainable_pct = read_meta_num(&dir, "trainable_pct");
                        rep.lr = read_meta_num(&dir, "lr");
                        session = Some(s);
                    } else {
                        let base = session.take().expect("validated plan: session exists");
                        // unpinned lr → the legacy grid tuning (no-op for the
                        // single-entry grids the shipped profiles use)
                        let lr = match lr {
                            Some(l) => *l,
                            None => self.tuned_lr(&ctx, &base, *mode, steps)?,
                        };
                        // fresh clone, exactly like the legacy retrain path
                        let mut s = ctx.clone_session(&base)?;
                        drop(base);
                        s.retrain(*mode, steps, lr)?;
                        let pct = 100.0 * s.mm.trainable_count(mode.trainable_key()) as f64
                            / s.mm.total_params() as f64;
                        rep.tps = Some(s.last_tps);
                        rep.trainable_pct = Some(pct);
                        rep.lr = Some(lr);
                        self.save_state(&s, &dir)?;
                        if let Some((_, lora)) = &s.lora {
                            io::save(&dir.join("lora.ptns"), &lora.tensors)
                                .context("saving adapters")?;
                        }
                        self.write_meta(
                            &dir,
                            stage,
                            vec![
                                ("tps", Json::Num(s.last_tps)),
                                ("trainable_pct", Json::Num(pct)),
                                ("lr", Json::Num(lr)),
                            ],
                        )?;
                        session = Some(s);
                    }
                }
                Stage::Reconstruct { mode, steps, lr } => {
                    let steps = steps.unwrap_or(self.cfg.recon_steps);
                    let lr = lr.unwrap_or(self.cfg.recon_lr);
                    let mut s = session.take().expect("validated plan: session exists");
                    if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                        rep.cache_hit = true;
                        self.load_state(&mut s, &dir)?;
                        rep.mean_improvement = read_meta_num(&dir, "mean_improvement");
                        session = Some(s);
                    } else {
                        let dense = pre_prune
                            .as_ref()
                            .expect("validated plan: reconstruct follows a prune");
                        let mut r = ctx.clone_session(&s)?;
                        drop(s);
                        let target = r.masks.clone();
                        let report =
                            reconstruct::reconstruct(&mut r, &target, dense, *mode, steps, lr)?;
                        rep.mean_improvement = Some(report.mean_improvement());
                        self.save_state(&r, &dir)?;
                        self.write_meta(
                            &dir,
                            stage,
                            vec![("mean_improvement", Json::Num(report.mean_improvement()))],
                        )?;
                        session = Some(r);
                    }
                }
                Stage::Merge => {
                    let mut s = session.take().expect("validated plan: session exists");
                    if self.hit(&dir, &["state.ptns", "masks.ptns", "meta.json"]) {
                        rep.cache_hit = true;
                        self.load_state(&mut s, &dir)?;
                        s.lora = None;
                    } else {
                        s.merge_adapters()?;
                        self.save_state(&s, &dir)?;
                        self.write_meta(&dir, stage, vec![])?;
                    }
                    session = Some(s);
                }
                Stage::Eval { tasks } => {
                    if self.hit(&dir, &["metrics.json"]) {
                        rep.cache_hit = true;
                        rep.metrics = Some(read_metrics(&dir.join("metrics.json"))?);
                    } else {
                        let s = session.as_mut().expect("validated plan: session exists");
                        let ppl = s.eval_ppl_test()?;
                        let (acc, per_task) = if *tasks {
                            let tr = s.eval_tasks()?;
                            (
                                crate::eval::mean_accuracy(&tr),
                                tr.into_iter()
                                    .map(|t| (t.name, t.accuracy))
                                    .collect::<Vec<_>>(),
                            )
                        } else {
                            (f64::NAN, Vec::new())
                        };
                        let m = EvalMetrics {
                            ppl: ppl.ppl,
                            loss: ppl.loss,
                            acc,
                            per_task,
                            sparsity: s.params.weight_sparsity(&s.mm),
                        };
                        write_metrics(&dir.join("metrics.json"), &m)?;
                        rep.metrics = Some(m);
                    }
                }
                Stage::Export { path } => {
                    // side effect outside the cache: always executed
                    let s = session.as_ref().expect("validated plan: session exists");
                    s.save(Path::new(path))?;
                }
            }

            rep.wall_s = t0.elapsed().as_secs_f64();
            if !self.quiet {
                let status = if rep.cache_hit {
                    "cache hit".to_string()
                } else {
                    format!("done in {:.2}s", rep.wall_s)
                };
                println!(
                    "[{}/{}] {:<28} {} (key {})",
                    i + 1,
                    total,
                    rep.label,
                    status,
                    &rep.key[..10]
                );
            }
            reports.push(rep);
        }

        let session = session.expect("validated plan: at least the pretrain stage ran");
        Ok((RunReport { plan: plan.name.clone(), stages: reports }, session))
    }

    /// The legacy lr-grid scan (mirrors `ExpContext::retrain_tuned`): train
    /// once per grid entry, evaluate test ppl merged (standard LoRA stays
    /// unmerged), return the winning lr.  Single-entry grids — every shipped
    /// profile — skip the scan, so `Retrain { lr: None }` costs nothing
    /// extra there; multi-entry grids pay one extra retrain of the winner
    /// (the stage then re-trains at that lr so its artifact is uniformly
    /// *unmerged*, keeping the explicit `merge` stage meaningful).
    fn tuned_lr(
        &self,
        ctx: &ExpContext<'rt>,
        base: &Session<'rt>,
        mode: Mode,
        steps: u64,
    ) -> Result<f64> {
        if self.cfg.lr_grid.len() == 1 {
            return Ok(self.cfg.lr_grid[0]);
        }
        let mut best: Option<(f64, f64)> = None; // (test ppl, lr)
        for &lr in &self.cfg.lr_grid {
            let mut s = ctx.clone_session(base)?;
            s.retrain(mode, steps, lr)?;
            if mode != Mode::Lora {
                s.merge_adapters()?;
            }
            let ppl = s.eval_ppl_test()?.ppl;
            if best.map(|(b, _)| ppl < b).unwrap_or(true) {
                best = Some((ppl, lr));
            }
        }
        Ok(best.expect("non-empty lr grid").1)
    }

    // ------------------------------------------------------------------
    // Artifact plumbing.
    // ------------------------------------------------------------------

    fn hit(&self, dir: &Path, needs: &[&str]) -> bool {
        !self.force && needs.iter().all(|f| dir.join(f).is_file())
    }

    fn save_state(&self, s: &Session, dir: &Path) -> Result<()> {
        io::save(&dir.join("state.ptns"), s.params.map()).context("saving stage weights")?;
        io::save(&dir.join("masks.ptns"), &s.masks.masks).context("saving stage masks")?;
        Ok(())
    }

    fn load_state(&self, s: &mut Session, dir: &Path) -> Result<()> {
        s.params = ParamStore::load(&s.mm, &dir.join("state.ptns"))?;
        s.masks = load_masks(&s.mm, &dir.join("masks.ptns"))?;
        // cached stage artifacts bypass prune()/merge(): recompress here
        s.refresh_sparse();
        Ok(())
    }

    /// Write `meta.json` — the completion marker, so it must come last.
    fn write_meta(&self, dir: &Path, stage: &Stage, extra: Vec<(&str, Json)>) -> Result<()> {
        let mut pairs = vec![("stage", stage.to_json())];
        pairs.extend(extra);
        write_json(&dir.join("meta.json"), &Json::obj(pairs))
    }
}

fn load_masks(mm: &ModelManifest, path: &Path) -> Result<MaskSet> {
    let loaded = io::load(path)?;
    let mut ms = MaskSet::default();
    for n in &mm.prunable {
        let t = loaded
            .get(n)
            .with_context(|| format!("mask artifact {path:?} missing {n:?}"))?;
        ms.set(n, t.clone());
    }
    Ok(ms)
}

fn load_lora(mm: &ModelManifest, path: &Path) -> Result<LoraState> {
    let loaded = io::load(path)?;
    let mut st = LoraState::default();
    for (name, shape) in &mm.adapters {
        let t = loaded
            .get(name)
            .with_context(|| format!("adapter artifact {path:?} missing {name:?}"))?;
        anyhow::ensure!(
            t.shape() == &shape[..],
            "adapter {name:?} shape {:?} vs manifest {:?}",
            t.shape(),
            shape
        );
        st.tensors.insert(name.clone(), t.clone());
    }
    Ok(st)
}

/// NaN/inf-safe number: serialized as null, read back as the given default.
fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn write_metrics(path: &Path, m: &EvalMetrics) -> Result<()> {
    let per_task = Json::Arr(
        m.per_task
            .iter()
            .map(|(name, acc)| {
                Json::obj(vec![("task", Json::Str(name.clone())), ("acc", num_or_null(*acc))])
            })
            .collect(),
    );
    write_json(
        path,
        &Json::obj(vec![
            ("ppl", num_or_null(m.ppl)),
            ("loss", num_or_null(m.loss)),
            ("acc", num_or_null(m.acc)),
            ("per_task", per_task),
            ("sparsity", num_or_null(m.sparsity)),
        ]),
    )
}

fn read_metrics(path: &Path) -> Result<EvalMetrics> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
    let num = |key: &str, default: f64| j.get(key).and_then(Json::as_f64).unwrap_or(default);
    let per_task = j
        .get("per_task")
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(|e| {
                    let name = e.get("task")?.as_str()?.to_string();
                    let acc = e.get("acc").and_then(Json::as_f64).unwrap_or(f64::NAN);
                    Some((name, acc))
                })
                .collect()
        })
        .unwrap_or_default();
    Ok(EvalMetrics {
        ppl: num("ppl", f64::INFINITY),
        loss: num("loss", f64::INFINITY),
        acc: num("acc", f64::NAN),
        per_task,
        sparsity: num("sparsity", 0.0),
    })
}

fn read_meta_num(dir: &Path, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(dir.join("meta.json")).ok()?;
    Json::parse(&text).ok()?.get(key).and_then(Json::as_f64)
}

/// Atomic-enough JSON write: temp file in the target directory, then rename.
/// The temp name is unique per (process, write) — like `io::save` — so
/// concurrent executors racing on one stage key never truncate each other's
/// in-flight marker.
fn write_json(path: &Path, j: &Json) -> Result<()> {
    let dir = path.parent().context("json path has no parent")?;
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let unique = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp{}-{unique}", std::process::id()));
    std::fs::write(&tmp, j.to_string()).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}
