"""Mask-generation Pallas kernels: magnitude threshold, N:M semi-structured,
and Wanda scores.

Exact-k selection (the global/uniform top-k) is a host-side sort and lives in
rust (rust/src/pruning); these kernels cover the device-side pieces a
production pipeline fuses into the weight pass:

* ``magnitude_threshold_mask``: |w| > thr elementwise (thr from the host).
* ``nm_mask``: keep the N largest-|w| within every group of M consecutive
  inputs — the 2:4 / 4:8 patterns of Mishra et al. (2021).  Rank is computed
  with an (m × m) pairwise comparison in VMEM, deterministic tie-break by
  in-group index (matches ref.semistructured_mask's stable argsort).
* ``wanda_score``: |W_ij| · ||X_j||₂ elementwise-broadcast (Sun et al. 2023).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv, pick_block


# ---------------------------------------------------------------------------
# Magnitude threshold mask.
# ---------------------------------------------------------------------------


def _thr_kernel(w_ref, t_ref, o_ref):
    o_ref[...] = (jnp.abs(w_ref[...]) > t_ref[0, 0]).astype(o_ref.dtype)


def magnitude_threshold_mask(w, thr):
    """mask = |w| > thr (thr a traced scalar)."""
    out, inp = w.shape
    bo = pick_block(out, 256)
    return pl.pallas_call(
        _thr_kernel,
        grid=(cdiv(out, bo),),
        in_specs=[
            pl.BlockSpec((bo, inp), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bo, inp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out, inp), w.dtype),
        interpret=INTERPRET,
    )(w, thr.reshape(1, 1).astype(w.dtype))


# ---------------------------------------------------------------------------
# N:M semi-structured mask.
# ---------------------------------------------------------------------------


def _nm_kernel(w_ref, o_ref, *, n: int, m: int):
    w = jnp.abs(w_ref[...])
    bo, bi = w.shape
    g = w.reshape(bo, bi // m, m)
    # rank_j = #{i : |w_i| > |w_j|  or  (|w_i| == |w_j| and i < j)}
    gi = g[:, :, :, None]  # i axis
    gj = g[:, :, None, :]  # j axis
    idx = jax.lax.iota(jnp.int32, m)
    tie = (gi == gj) & (idx[:, None] < idx[None, :])
    rank = jnp.sum((gi > gj) | tie, axis=2)  # (bo, groups, m)
    keep = (rank < n).astype(o_ref.dtype)
    o_ref[...] = keep.reshape(bo, bi)


def nm_mask(w, n: int, m: int):
    """N:M mask along the input dim of w:(out, in); in % m == 0."""
    out, inp = w.shape
    assert inp % m == 0, (inp, m)
    bo = pick_block(out, 128)
    return pl.pallas_call(
        functools.partial(_nm_kernel, n=n, m=m),
        grid=(cdiv(out, bo),),
        in_specs=[pl.BlockSpec((bo, inp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bo, inp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out, inp), w.dtype),
        interpret=INTERPRET,
    )(w)


# ---------------------------------------------------------------------------
# Wanda scores.
# ---------------------------------------------------------------------------


def _wanda_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * n_ref[...]


def wanda_score(w, x_norm):
    """S = |W| * ||X||₂ broadcast over rows; x_norm: (in,)."""
    out, inp = w.shape
    bo = pick_block(out, 256)
    return pl.pallas_call(
        _wanda_kernel,
        grid=(cdiv(out, bo),),
        in_specs=[
            pl.BlockSpec((bo, inp), lambda i: (i, 0)),
            pl.BlockSpec((1, inp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bo, inp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((out, inp), w.dtype),
        interpret=INTERPRET,
    )(w, x_norm[None, :])
